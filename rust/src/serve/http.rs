//! HTTP/1.1 streaming front door (DESIGN.md §HTTP-Front-Door).
//!
//! A hand-rolled server on [`std::net::TcpListener`] — the crate carries
//! no async runtime or web framework, and the serving stack underneath is
//! thread-per-replica already, so the front door follows the same idiom:
//! one bounded accept loop, one short-stack handler thread per live
//! connection, gated by an active-connection bound rather than a small
//! fixed pool (an SSE stream holds its connection for the whole
//! generation, so the bound must cover thousands of concurrent streams,
//! not a worker count).
//!
//! Endpoints:
//!
//! * `POST /v1/score` — score a token sequence; blocks for the
//!   [`Response`] and returns it as JSON.
//! * `POST /v1/generate` — KV-cached generation streamed as Server-Sent
//!   Events: a `start` event carrying the admission id, one `token` event
//!   per [`StreamEvent::Token`], and a terminal `done` event carrying the
//!   [`FinishReason`] plus the final [`Response`] (or `null` when the
//!   generation ended cancelled/failed).
//! * `POST /v1/cancel/{id}` — step-granular cancellation by admission id.
//! * `GET /healthz`, `GET /metrics` — liveness and a Prometheus scrape of
//!   the live [`ServerReport`] ([`crate::obs::export::prometheus_text`]).
//!
//! **Disconnect is cancel.** A failed write onto a streaming connection
//! cancels the ticket, so the decode loop sheds the sequence at the next
//! step boundary and the admission ledger's accounting identity
//! (`admitted == responses + cancelled + failed`) keeps holding with
//! clients that vanish mid-stream — the same path `/v1/cancel` takes,
//! just triggered by the socket instead of a request.
//!
//! **Load shedding speaks HTTP.** [`Admission::Rejected`] maps onto 429
//! (queue/deadline/quota sheds) and 503 (KV exhaustion), both carrying a
//! `Retry-After` header derived from the admission controller's
//! `retry_after` estimate; connections beyond the active bound get an
//! immediate 503 before the request line is even read.
//!
//! Wire rigor: responses and SSE `data:` payloads are emitted through the
//! ASCII-safe incremental [`JsonWriter`] (no raw newline or non-ASCII
//! byte can appear inside a frame), and request bodies go through the
//! strict [`Json`] parser (depth-capped, surrogate-validating) behind a
//! per-endpoint field allowlist — unknown or ill-typed fields are a 400,
//! not a silent default.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{Cluster, HttpReport, ServerReport};
use crate::obs::{
    EventKind, Observatory, ProvenanceLedger, SpanCollector, Track, TraceClock, TraceConfig,
    TraceEvent,
};
use crate::ser::{Json, JsonWriter};

use super::queue::Response;
use super::request::{
    Admission, FinishReason, Priority, QosClass, RejectReason, ServeRequest, StreamEvent, Ticket,
};

/// Request/header line bound: longer lines are a 400, not a bigger buffer.
const MAX_LINE: u64 = 8 * 1024;
/// Header count bound.
const MAX_HEADERS: usize = 64;
/// Handler thread stack. Deliberately small — thousands of concurrent
/// streams each hold one — and safe because the JSON parser caps its
/// recursion depth.
const HANDLER_STACK: usize = 512 * 1024;
/// Socket write budget: a client that stops reading its stream for this
/// long counts as disconnected (and is therefore cancelled).
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

// ---------------------------------------------------------------------------
// Backend abstraction
// ---------------------------------------------------------------------------

/// What the front door needs from the serving stack: typed non-blocking
/// submission and a live metrics snapshot. [`Cluster`] is the production
/// implementation; tests substitute mocks (in-crate, since fabricating
/// [`Ticket`]s needs crate-private fields) or always-rejecting stubs.
pub trait HttpBackend: Send + Sync {
    fn try_submit(&self, req: ServeRequest) -> Result<Admission>;
    fn live_report(&self) -> ServerReport;
    fn replicas(&self) -> usize;
    /// Time-series registry behind `/v1/status` and `/debug` (None = the
    /// backend records no series; both pages degrade gracefully).
    fn observatory(&self) -> Option<Arc<Observatory>> {
        None
    }
    /// Plan-provenance ledger behind the same pages (None = no ledger).
    fn provenance(&self) -> Option<Arc<ProvenanceLedger>> {
        None
    }
}

impl HttpBackend for Cluster {
    fn try_submit(&self, req: ServeRequest) -> Result<Admission> {
        Cluster::try_submit(self, req)
    }

    fn live_report(&self) -> ServerReport {
        Cluster::live_report(self)
    }

    fn replicas(&self) -> usize {
        Cluster::replicas(self)
    }

    fn observatory(&self) -> Option<Arc<Observatory>> {
        Some(Cluster::observatory(self))
    }

    fn provenance(&self) -> Option<Arc<ProvenanceLedger>> {
        Some(Cluster::provenance(self))
    }
}

// ---------------------------------------------------------------------------
// Config, stats, server handle
// ---------------------------------------------------------------------------

/// Front-door knobs.
#[derive(Clone, Debug)]
pub struct HttpConfig {
    /// Bind address; port 0 picks a free port (see [`HttpServer::addr`]).
    pub addr: String,
    /// Active-connection bound: accepts beyond it get an immediate
    /// 503 + `Retry-After: 1` without reading the request.
    pub max_connections: usize,
    /// Request body bound (413 beyond it).
    pub max_body_bytes: usize,
    /// Score-wait budget, socket read budget, and the wait for the final
    /// generation [`Response`] after the stream's `Done`.
    pub request_timeout: Duration,
    /// Per-event budget on a generation stream; a stream silent for this
    /// long is cancelled and closed with a `failed` terminal event.
    pub stream_event_timeout: Duration,
    /// Span collection for the http track ([`EventKind::HttpConn`]).
    pub trace: TraceConfig,
    /// Trace timebase — pass the cluster's clock so http spans align with
    /// admission/router/replica spans in the merged trace.
    pub clock: TraceClock,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig {
            addr: "127.0.0.1:0".to_string(),
            max_connections: 2048,
            max_body_bytes: 1 << 20,
            request_timeout: Duration::from_secs(120),
            stream_event_timeout: Duration::from_secs(120),
            trace: TraceConfig::default(),
            clock: TraceClock::new(),
        }
    }
}

/// Lock-free front-door counters ([`HttpReport`] is the snapshot).
#[derive(Default)]
struct HttpStats {
    connections: AtomicUsize,
    rejected_busy: AtomicUsize,
    disconnects: AtomicUsize,
    sse_events: AtomicUsize,
    bytes_out: AtomicUsize,
    active: AtomicUsize,
    peak: AtomicUsize,
}

impl HttpStats {
    fn snapshot(&self) -> HttpReport {
        HttpReport {
            connections: self.connections.load(Ordering::SeqCst),
            rejected_busy: self.rejected_busy.load(Ordering::SeqCst),
            disconnects: self.disconnects.load(Ordering::SeqCst),
            sse_events: self.sse_events.load(Ordering::SeqCst),
            bytes_out: self.bytes_out.load(Ordering::SeqCst),
            peak_connections: self.peak.load(Ordering::SeqCst),
        }
    }

    fn enter(&self) {
        let now = self.active.fetch_add(1, Ordering::SeqCst) + 1;
        self.peak.fetch_max(now, Ordering::SeqCst);
    }

    fn exit(&self) {
        self.active.fetch_sub(1, Ordering::SeqCst);
    }
}

/// State shared by the accept loop and every handler thread.
struct Shared {
    backend: Arc<dyn HttpBackend>,
    cfg: HttpConfig,
    stats: HttpStats,
    /// Admission id → cancel flag of every request currently being
    /// served over HTTP — what `POST /v1/cancel/{id}` flips. Entries are
    /// removed when their handler finishes, so a cancel for a finished id
    /// is a 404, matching ticket semantics (cancel is step-granular and
    /// only meaningful while the request is live).
    cancels: Mutex<HashMap<u64, Arc<AtomicBool>>>,
    tracer: Mutex<SpanCollector>,
    shutdown: AtomicBool,
}

impl Shared {
    fn register_cancel(&self, ticket: &Ticket) {
        self.cancels.lock().unwrap().insert(ticket.id(), ticket.cancel.clone());
    }

    fn unregister_cancel(&self, id: u64) {
        self.cancels.lock().unwrap().remove(&id);
    }
}

/// Handle to a running front door. [`shutdown`](Self::shutdown) is
/// graceful: it stops accepting, then joins every in-flight handler —
/// after it returns, no clone of the backend `Arc` survives on a server
/// thread (a bench can `Arc::try_unwrap` its cluster back).
pub struct HttpServer {
    addr: SocketAddr,
    accept: Option<thread::JoinHandle<Vec<thread::JoinHandle<()>>>>,
    shared: Arc<Shared>,
}

impl HttpServer {
    /// Bind `cfg.addr` and start serving `backend`.
    pub fn start(backend: Arc<dyn HttpBackend>, cfg: HttpConfig) -> Result<HttpServer> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
        let addr = listener.local_addr().context("listener local_addr")?;
        let tracer = SpanCollector::new(cfg.clock.clone(), Track::Http, cfg.trace);
        let shared = Arc::new(Shared {
            backend,
            cfg,
            stats: HttpStats::default(),
            cancels: Mutex::new(HashMap::new()),
            tracer: Mutex::new(tracer),
            shutdown: AtomicBool::new(false),
        });
        let sh = shared.clone();
        let accept = thread::Builder::new()
            .name("mxmoe-http-accept".to_string())
            .spawn(move || accept_loop(listener, sh))
            .context("spawn http accept thread")?;
        Ok(HttpServer { addr, accept: Some(accept), shared })
    }

    /// The bound address (the actual port when `addr` asked for port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Front-door counters so far.
    pub fn http_report(&self) -> HttpReport {
        self.shared.stats.snapshot()
    }

    /// Live cluster report with the http block filled in — the same
    /// snapshot `GET /metrics` serves.
    pub fn report(&self) -> ServerReport {
        let mut r = self.shared.backend.live_report();
        r.http = self.shared.stats.snapshot();
        r
    }

    /// Drain the http-track span ring (`(events, dropped)`).
    pub fn take_trace(&self) -> (Vec<TraceEvent>, usize) {
        self.shared.tracer.lock().unwrap().drain()
    }

    /// Stop accepting, join the accept loop and every handler thread,
    /// and return the final front-door counters.
    pub fn shutdown(mut self) -> HttpReport {
        self.stop();
        self.shared.stats.snapshot()
    }

    fn stop(&mut self) {
        let Some(accept) = self.accept.take() else {
            return;
        };
        self.shared.shutdown.store(true, Ordering::Release);
        // wake the blocked accept(2) with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        let handlers = accept.join().expect("http accept thread panicked");
        for h in handlers {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

// ---------------------------------------------------------------------------
// Accept loop
// ---------------------------------------------------------------------------

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) -> Vec<thread::JoinHandle<()>> {
    let mut handlers: Vec<thread::JoinHandle<()>> = Vec::new();
    let mut conn_seq = 0u64;
    for incoming in listener.incoming() {
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        let mut stream = match incoming {
            Ok(s) => s,
            Err(_) => continue,
        };
        // bound memory on a long-running server: drop finished handles
        handlers.retain(|h| !h.is_finished());
        if shared.stats.active.load(Ordering::SeqCst) >= shared.cfg.max_connections {
            shared.stats.rejected_busy.fetch_add(1, Ordering::SeqCst);
            let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
            let _ = write_response(
                &mut stream,
                503,
                "application/json",
                &[("retry-after", "1".to_string())],
                &error_body("server at connection capacity"),
            );
            continue;
        }
        shared.stats.connections.fetch_add(1, Ordering::SeqCst);
        // enter BEFORE spawn so the bound can never overshoot between
        // accept and handler start
        shared.stats.enter();
        conn_seq += 1;
        let sh = shared.clone();
        let spawned = thread::Builder::new()
            .name(format!("mxmoe-http-{conn_seq}"))
            .stack_size(HANDLER_STACK)
            .spawn(move || {
                let t0 = sh.cfg.clock.now_us();
                let out = handle_conn(&sh, stream);
                let dur = sh.cfg.clock.now_us().saturating_sub(t0);
                sh.stats.bytes_out.fetch_add(out.bytes, Ordering::SeqCst);
                sh.stats.sse_events.fetch_add(out.events, Ordering::SeqCst);
                if out.disconnected {
                    sh.stats.disconnects.fetch_add(1, Ordering::SeqCst);
                }
                sh.tracer.lock().unwrap().span(
                    t0,
                    dur,
                    out.req,
                    EventKind::HttpConn {
                        endpoint: out.endpoint,
                        status: out.status,
                        bytes: out.bytes,
                        events: out.events,
                        disconnected: out.disconnected,
                    },
                );
                sh.stats.exit();
            });
        match spawned {
            Ok(h) => handlers.push(h),
            Err(_) => shared.stats.exit(),
        }
    }
    handlers
}

// ---------------------------------------------------------------------------
// Per-connection handling
// ---------------------------------------------------------------------------

/// What one connection came to: the span payload plus the request id (0
/// when the request never reached admission).
struct ConnOutcome {
    endpoint: &'static str,
    status: u16,
    bytes: usize,
    events: usize,
    disconnected: bool,
    req: u64,
}

/// Structured failure on the way to a response: an HTTP status plus a
/// JSON error message (and the `Allow` header for 405s).
struct HttpError {
    status: u16,
    msg: String,
    allow: Option<&'static str>,
}

fn fail(status: u16, msg: impl Into<String>) -> HttpError {
    HttpError { status, msg: msg.into(), allow: None }
}

fn handle_conn(shared: &Shared, mut stream: TcpStream) -> ConnOutcome {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(shared.cfg.request_timeout));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut out = ConnOutcome {
        endpoint: "bad-request",
        status: 0,
        bytes: 0,
        events: 0,
        disconnected: false,
        req: 0,
    };
    match read_request(&mut stream, shared.cfg.max_body_bytes) {
        Err(e) => write_error(&mut stream, &mut out, &e),
        Ok(req) => {
            if let Err(e) = route(shared, &mut stream, &req, &mut out) {
                write_error(&mut stream, &mut out, &e);
            }
        }
    }
    out
}

fn route(
    shared: &Shared,
    stream: &mut TcpStream,
    req: &HttpRequest,
    out: &mut ConnOutcome,
) -> Result<(), HttpError> {
    let method = req.method.as_str();
    let path = req.path.as_str();
    match path {
        "/healthz" => {
            out.endpoint = "healthz";
            require_method(method, "GET")?;
            let mut w = JsonWriter::new();
            w.begin_obj();
            w.field_str("status", "ok");
            w.field_u64("replicas", shared.backend.replicas() as u64);
            w.end_obj();
            send(stream, out, 200, "application/json", &[], w.finish());
            Ok(())
        }
        "/metrics" => {
            out.endpoint = "metrics";
            require_method(method, "GET")?;
            let mut r = shared.backend.live_report();
            r.http = shared.stats.snapshot();
            let snap = shared.backend.observatory().map(|o| o.snapshot());
            let text = crate::obs::export::prometheus_text_with(&r, snap.as_ref());
            send(stream, out, 200, "text/plain; version=0.0.4", &[], &text);
            Ok(())
        }
        "/v1/status" => {
            out.endpoint = "status";
            require_method(method, "GET")?;
            let mut r = shared.backend.live_report();
            r.http = shared.stats.snapshot();
            let snap = shared.backend.observatory().map(|o| o.snapshot());
            let plans = shared.backend.provenance().map(|p| p.records()).unwrap_or_default();
            let text = crate::obs::export::status_json(&r, snap.as_ref(), &plans);
            send(stream, out, 200, "application/json", &[], &text);
            Ok(())
        }
        "/debug" => {
            out.endpoint = "debug";
            require_method(method, "GET")?;
            let mut r = shared.backend.live_report();
            r.http = shared.stats.snapshot();
            let snap = shared.backend.observatory().map(|o| o.snapshot());
            let plans = shared.backend.provenance().map(|p| p.records()).unwrap_or_default();
            let html = crate::obs::export::debug_html(&r, snap.as_ref(), &plans);
            send(stream, out, 200, "text/html; charset=utf-8", &[], &html);
            Ok(())
        }
        "/v1/score" => {
            out.endpoint = "score";
            require_method(method, "POST")?;
            score(shared, stream, &req.body, out)
        }
        "/v1/generate" => {
            out.endpoint = "generate";
            require_method(method, "POST")?;
            generate(shared, stream, &req.body, out)
        }
        p if p.starts_with("/v1/cancel/") => {
            out.endpoint = "cancel";
            require_method(method, "POST")?;
            cancel(shared, stream, &p["/v1/cancel/".len()..], out)
        }
        p => {
            out.endpoint = "not-found";
            Err(fail(404, format!("no such endpoint: {p}")))
        }
    }
}

fn require_method(method: &str, want: &'static str) -> Result<(), HttpError> {
    if method == want {
        Ok(())
    } else {
        Err(HttpError {
            status: 405,
            msg: format!("method {method} not allowed"),
            allow: Some(want),
        })
    }
}

// ---------------------------------------------------------------------------
// Endpoints
// ---------------------------------------------------------------------------

fn score(
    shared: &Shared,
    stream: &mut TcpStream,
    body: &[u8],
    out: &mut ConnOutcome,
) -> Result<(), HttpError> {
    let req = parse_score_body(body)?;
    let ticket = match submit(shared, req)? {
        Submitted::Rejected => return Ok(()), // reply already written by submit()
        Submitted::Ticket(t) => t,
    };
    out.req = ticket.id();
    shared.register_cancel(&ticket);
    let waited = ticket.wait_timeout(shared.cfg.request_timeout);
    shared.unregister_cancel(ticket.id());
    match waited {
        Ok(resp) => {
            let mut w = JsonWriter::new();
            w.begin_obj();
            w.field_u64("id", ticket.id());
            response_fields(&mut w, &resp);
            w.end_obj();
            send(stream, out, 200, "application/json", &[], w.finish());
            Ok(())
        }
        Err(_) if ticket.is_cancelled() => {
            Err(fail(409, format!("request {} cancelled", ticket.id())))
        }
        Err(e) => Err(fail(504, format!("request {}: {e}", ticket.id()))),
    }
}

fn generate(
    shared: &Shared,
    stream: &mut TcpStream,
    body: &[u8],
    out: &mut ConnOutcome,
) -> Result<(), HttpError> {
    let req = parse_generate_body(body)?;
    let ticket = match submit(shared, req)? {
        Submitted::Rejected => return Ok(()),
        Submitted::Ticket(t) => t,
    };
    out.req = ticket.id();
    shared.register_cancel(&ticket);
    stream_generation(shared, stream, &ticket, out);
    shared.unregister_cancel(ticket.id());
    Ok(())
}

/// Everything after admission on a generation connection: SSE headers,
/// `start`, one `token` per stream event, and exactly one terminal
/// `done`. A failed socket write anywhere flips the ticket's cancel flag
/// (disconnect-as-cancel) and stops the stream.
fn stream_generation(
    shared: &Shared,
    stream: &mut TcpStream,
    ticket: &Ticket,
    out: &mut ConnOutcome,
) {
    let head = "HTTP/1.1 200 OK\r\ncontent-type: text/event-stream\r\ncache-control: no-cache\r\nconnection: close\r\n\r\n";
    if stream.write_all(head.as_bytes()).is_err() {
        out.disconnected = true;
        ticket.cancel();
        return;
    }
    out.status = 200;
    out.bytes += head.len();

    let mut w = JsonWriter::new();
    w.begin_obj();
    w.field_u64("id", ticket.id());
    w.end_obj();
    if !write_sse(stream, out, "start", w.finish()) {
        ticket.cancel();
        return;
    }

    let mut streamed = 0u64;
    loop {
        match ticket.wait_event(shared.cfg.stream_event_timeout) {
            Ok(StreamEvent::Token { token, index }) => {
                w.reset();
                w.begin_obj();
                w.field_u64("token", u64::from(token));
                w.field_u64("index", index as u64);
                w.end_obj();
                if !write_sse(stream, out, "token", w.finish()) {
                    ticket.cancel();
                    return;
                }
                streamed += 1;
            }
            Ok(StreamEvent::Done { reason, generated }) => {
                // the final Response only exists for served generations;
                // cancelled/failed ones never get one (ticket contract)
                let resp = if matches!(reason, FinishReason::Stop | FinishReason::Length) {
                    ticket.wait_timeout(shared.cfg.request_timeout).ok()
                } else {
                    None
                };
                w.reset();
                w.begin_obj();
                w.field_str("reason", finish_name(reason));
                w.field_u64("generated", generated as u64);
                w.key("response");
                match resp {
                    Some(r) => {
                        w.begin_obj();
                        response_fields(&mut w, &r);
                        w.end_obj();
                    }
                    None => w.null_val(),
                }
                w.end_obj();
                if !write_sse(stream, out, "done", w.finish()) {
                    ticket.cancel();
                }
                return;
            }
            Err(_) => {
                // cancelled (`/v1/cancel` or a prior disconnect), the
                // stream closed without Done (replica died), or the
                // per-event budget expired — cancel so the serving side
                // sheds, then tell the client which it was
                let reason = if ticket.is_cancelled() { "cancelled" } else { "failed" };
                ticket.cancel();
                w.reset();
                w.begin_obj();
                w.field_str("reason", reason);
                w.field_u64("generated", streamed);
                w.key("response");
                w.null_val();
                w.end_obj();
                if !write_sse(stream, out, "done", w.finish()) {
                    // already cancelled above; just note the disconnect
                }
                return;
            }
        }
    }
}

fn cancel(
    shared: &Shared,
    stream: &mut TcpStream,
    id_text: &str,
    out: &mut ConnOutcome,
) -> Result<(), HttpError> {
    let id: u64 = id_text
        .parse()
        .map_err(|_| fail(400, format!("bad request id '{id_text}'")))?;
    let flag = shared.cancels.lock().unwrap().get(&id).cloned();
    match flag {
        Some(flag) => {
            flag.store(true, Ordering::Release);
            let mut w = JsonWriter::new();
            w.begin_obj();
            w.field_u64("id", id);
            w.field_bool("cancelled", true);
            w.end_obj();
            send(stream, out, 200, "application/json", &[], w.finish());
            Ok(())
        }
        None => Err(fail(404, format!("no live request {id}"))),
    }
}

/// Outcome of [`submit`]: a ticket, or a rejection whose HTTP reply was
/// already written.
enum Submitted {
    Ticket(Ticket),
    Rejected,
}

/// Run a [`ServeRequest`] through the backend and translate load shedding
/// into HTTP: 429 for queue-side sheds, 503 for KV exhaustion, both with
/// `Retry-After` from the admission controller's estimate.
fn submit(shared: &Shared, req: ServeRequest) -> Result<Submitted, HttpError> {
    // the stream is not available here; rejection replies are written by
    // the caller via the returned error/outcome. To keep replies near the
    // mapping, submit() only classifies; see score()/generate().
    match shared.backend.try_submit(req) {
        Err(e) => Err(fail(400, format!("rejected: {e}"))),
        Ok(Admission::Admitted(t)) => Ok(Submitted::Ticket(t)),
        Ok(Admission::Rejected { id, reason, retry_after }) => {
            let status = match reason {
                RejectReason::KvExhausted => 503,
                _ => 429,
            };
            let mut e = fail(status, String::new());
            e.msg = shed_body(id, reason, retry_after);
            Err(e)
        }
    }
}

/// Marker prefix telling [`write_error`] the message is a pre-built JSON
/// body with a Retry-After hint, not a plain error string.
const SHED_MARK: &str = "\u{1}shed:";

fn shed_body(id: u64, reason: RejectReason, retry_after: Duration) -> String {
    let retry_secs = (retry_after.as_secs_f64().ceil() as u64).max(1);
    let mut w = JsonWriter::new();
    w.begin_obj();
    w.field_str("error", "rejected");
    w.field_str("reason", reason.name());
    w.field_u64("retry_after_ms", retry_after.as_millis() as u64);
    w.field_u64("id", id);
    w.end_obj();
    format!("{SHED_MARK}{retry_secs}:{}", w.finish())
}

// ---------------------------------------------------------------------------
// Body parsing (strict, allowlisted)
// ---------------------------------------------------------------------------

fn parse_body_json(body: &[u8]) -> Result<Json, HttpError> {
    let text =
        std::str::from_utf8(body).map_err(|_| fail(400, "body is not valid UTF-8"))?;
    Json::parse(text).map_err(|e| fail(400, format!("body: {e}")))
}

fn allow_keys(j: &Json, allowed: &[&str]) -> Result<(), HttpError> {
    match j {
        Json::Obj(m) => {
            for k in m.keys() {
                if !allowed.contains(&k.as_str()) {
                    return Err(fail(400, format!("unknown field '{k}'")));
                }
            }
            Ok(())
        }
        _ => Err(fail(400, "body must be a JSON object")),
    }
}

fn parse_token_array(j: &Json, key: &str, required: bool) -> Result<Vec<u32>, HttpError> {
    let Some(v) = j.get(key) else {
        return if required {
            Err(fail(400, format!("'{key}' is required")))
        } else {
            Ok(Vec::new())
        };
    };
    let arr = v
        .as_arr()
        .ok_or_else(|| fail(400, format!("'{key}' must be an array of token ids")))?;
    arr.iter()
        .map(|t| {
            t.as_usize()
                .filter(|&x| x <= u32::MAX as usize)
                .map(|x| x as u32)
                .ok_or_else(|| fail(400, format!("'{key}' entries must be u32 token ids")))
        })
        .collect()
}

fn apply_knobs(mut req: ServeRequest, j: &Json) -> Result<ServeRequest, HttpError> {
    if let Some(p) = j.get("priority") {
        let p = p.as_str().ok_or_else(|| fail(400, "'priority' must be a string"))?;
        req = req.priority(match p {
            "low" => Priority::Low,
            "normal" => Priority::Normal,
            "high" => Priority::High,
            other => return Err(fail(400, format!("unknown priority '{other}'"))),
        });
    }
    if let Some(q) = j.get("qos") {
        let q = q.as_str().ok_or_else(|| fail(400, "'qos' must be a string"))?;
        req = req.qos(match q {
            "interactive" => QosClass::Interactive,
            "standard" => QosClass::Standard,
            "batch" => QosClass::Batch,
            other => return Err(fail(400, format!("unknown qos '{other}'"))),
        });
    }
    if let Some(d) = j.get("deadline_ms") {
        let ms = d
            .as_usize()
            .filter(|&ms| ms >= 1)
            .ok_or_else(|| fail(400, "'deadline_ms' must be a positive integer"))?;
        req = req.deadline(Duration::from_millis(ms as u64));
    }
    Ok(req)
}

fn parse_score_body(body: &[u8]) -> Result<ServeRequest, HttpError> {
    let j = parse_body_json(body)?;
    allow_keys(&j, &["tokens", "priority", "qos", "deadline_ms"])?;
    let tokens = parse_token_array(&j, "tokens", true)?;
    if tokens.is_empty() {
        return Err(fail(400, "'tokens' must be non-empty"));
    }
    apply_knobs(ServeRequest::new(tokens), &j)
}

fn parse_generate_body(body: &[u8]) -> Result<ServeRequest, HttpError> {
    let j = parse_body_json(body)?;
    allow_keys(&j, &["tokens", "max_new_tokens", "stop", "priority", "qos", "deadline_ms"])?;
    let tokens = parse_token_array(&j, "tokens", true)?;
    if tokens.is_empty() {
        return Err(fail(400, "'tokens' must be non-empty"));
    }
    let max_new = j
        .get("max_new_tokens")
        .and_then(Json::as_usize)
        .filter(|&n| n >= 1)
        .ok_or_else(|| fail(400, "'max_new_tokens' must be a positive integer"))?;
    let stop = parse_token_array(&j, "stop", false)?;
    apply_knobs(ServeRequest::generate(tokens, max_new, stop), &j)
}

// ---------------------------------------------------------------------------
// HTTP reading
// ---------------------------------------------------------------------------

struct HttpRequest {
    method: String,
    path: String,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl HttpRequest {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }
}

/// One bounded CRLF line; `None` at clean EOF. An unterminated line at
/// the bound is malformed, not a bigger buffer.
fn read_line<R: BufRead>(r: &mut R) -> Result<Option<String>, HttpError> {
    let mut buf = Vec::new();
    let n = r
        .by_ref()
        .take(MAX_LINE)
        .read_until(b'\n', &mut buf)
        .map_err(|e| fail(400, format!("read: {e}")))?;
    if n == 0 {
        return Ok(None);
    }
    if buf.last() != Some(&b'\n') {
        return Err(fail(400, "header line too long or truncated"));
    }
    buf.pop();
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    String::from_utf8(buf)
        .map(Some)
        .map_err(|_| fail(400, "header line is not valid UTF-8"))
}

fn read_request(stream: &mut TcpStream, max_body: usize) -> Result<HttpRequest, HttpError> {
    let mut reader = BufReader::new(
        stream.try_clone().map_err(|e| fail(500, format!("clone stream: {e}")))?,
    );
    let line = read_line(&mut reader)?.ok_or_else(|| fail(400, "empty request"))?;
    let mut parts = line.split(' ');
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) if !m.is_empty() && p.starts_with('/') => {
            (m.to_string(), p.to_string(), v)
        }
        _ => return Err(fail(400, "malformed request line")),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(fail(400, format!("unsupported protocol '{version}'")));
    }
    let mut headers = Vec::new();
    loop {
        let line = read_line(&mut reader)?.ok_or_else(|| fail(400, "truncated headers"))?;
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(fail(400, "too many headers"));
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| fail(400, format!("malformed header line '{line}'")))?;
        if name.is_empty() || name.contains(' ') {
            return Err(fail(400, format!("malformed header name '{name}'")));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }
    let mut req = HttpRequest { method, path, headers, body: Vec::new() };
    if req.method == "POST" {
        if req.header("transfer-encoding").is_some() {
            return Err(fail(400, "chunked bodies not supported"));
        }
        let len: usize = req
            .header("content-length")
            .ok_or_else(|| fail(411, "Content-Length required"))?
            .parse()
            .map_err(|_| fail(400, "bad Content-Length"))?;
        if len > max_body {
            return Err(fail(413, format!("body exceeds {max_body} bytes")));
        }
        let mut body = vec![0u8; len];
        reader
            .read_exact(&mut body)
            .map_err(|_| fail(400, "truncated body"))?;
        req.body = body;
    }
    Ok(req)
}

// ---------------------------------------------------------------------------
// HTTP writing
// ---------------------------------------------------------------------------

fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Error",
    }
}

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    extra: &[(&str, String)],
    body: &str,
) -> std::io::Result<usize> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: {content_type}\r\ncontent-length: {}\r\nconnection: close\r\n",
        reason_phrase(status),
        body.len(),
    );
    for (k, v) in extra {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    Ok(head.len() + body.len())
}

/// Write a response and fold the result into the connection outcome.
fn send(
    stream: &mut TcpStream,
    out: &mut ConnOutcome,
    status: u16,
    content_type: &str,
    extra: &[(&str, String)],
    body: &str,
) {
    out.status = status;
    match write_response(stream, status, content_type, extra, body) {
        Ok(n) => out.bytes += n,
        Err(_) => out.disconnected = true,
    }
}

/// One SSE frame (`event:` + `data:` + blank line); `false` when the
/// client is gone. The payload is ASCII-safe JSON, so no raw newline can
/// break the framing.
fn write_sse(stream: &mut TcpStream, out: &mut ConnOutcome, event: &str, data: &str) -> bool {
    let frame = format!("event: {event}\ndata: {data}\n\n");
    match stream.write_all(frame.as_bytes()) {
        Ok(()) => {
            out.bytes += frame.len();
            out.events += 1;
            true
        }
        Err(_) => {
            out.disconnected = true;
            false
        }
    }
}

fn write_error(stream: &mut TcpStream, out: &mut ConnOutcome, e: &HttpError) {
    // shed rejections carry a prebuilt JSON body + Retry-After hint
    if let Some(rest) = e.msg.strip_prefix(SHED_MARK) {
        if let Some((secs, body)) = rest.split_once(':') {
            send(
                stream,
                out,
                e.status,
                "application/json",
                &[("retry-after", secs.to_string())],
                body,
            );
            return;
        }
    }
    let mut extra: Vec<(&str, String)> = Vec::new();
    if let Some(allow) = e.allow {
        extra.push(("allow", allow.to_string()));
    }
    send(stream, out, e.status, "application/json", &extra, &error_body(&e.msg));
}

fn error_body(msg: &str) -> String {
    let mut w = JsonWriter::new();
    w.begin_obj();
    w.field_str("error", msg);
    w.end_obj();
    w.finish().to_string()
}

fn response_fields(w: &mut JsonWriter, resp: &Response) {
    w.field_u64("next_token", u64::from(resp.next_token));
    w.field_f64("mean_nll", resp.mean_nll);
    w.field_f64("latency_ms", resp.latency.as_secs_f64() * 1e3);
    w.field_f64("queue_wait_ms", resp.queue_wait.as_secs_f64() * 1e3);
    w.field_u64("generation", resp.generation);
}

fn finish_name(reason: FinishReason) -> &'static str {
    match reason {
        FinishReason::Stop => "stop",
        FinishReason::Length => "length",
        FinishReason::Cancelled => "cancelled",
        FinishReason::Failed => "failed",
    }
}

// ---------------------------------------------------------------------------
// Tests (in-crate: fabricating Tickets needs crate-private fields).
// The malformed-HTTP/body catalog and the real-cluster integration tests
// live in tests/http_serve.rs.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    /// Scripted backend: one canned behaviour per submission, in order.
    enum Script {
        /// Admit a scoring ticket and reply immediately.
        Score(Response),
        /// Admit a generation ticket and stream these events, then (for
        /// served finishes) the response.
        Generate(Vec<StreamEvent>, Option<Response>),
        /// Admit a generation ticket and keep streaming tokens until
        /// cancelled — the replier thread watches the cancel flag like a
        /// decode loop watches it between steps, then sends Done.
        GenerateUntilCancel,
        /// Reject with this reason.
        Reject(RejectReason),
    }

    struct MockBackend {
        script: Mutex<Vec<Script>>,
        next_id: AtomicUsize,
    }

    impl MockBackend {
        fn new(script: Vec<Script>) -> Arc<MockBackend> {
            Arc::new(MockBackend { script: Mutex::new(script), next_id: AtomicUsize::new(1) })
        }
    }

    fn resp(next_token: u32) -> Response {
        Response {
            next_token,
            mean_nll: 0.25,
            latency: Duration::from_millis(2),
            queue_wait: Duration::from_millis(1),
            generation: 0,
        }
    }

    impl HttpBackend for MockBackend {
        fn try_submit(&self, _req: ServeRequest) -> Result<Admission> {
            let mut script = self.script.lock().unwrap();
            anyhow::ensure!(!script.is_empty(), "mock script exhausted");
            let step = script.remove(0);
            let id = self.next_id.fetch_add(1, Ordering::SeqCst) as u64;
            let cancel = Arc::new(AtomicBool::new(false));
            match step {
                Script::Reject(reason) => Ok(Admission::Rejected {
                    id,
                    reason,
                    retry_after: Duration::from_millis(1500),
                }),
                Script::Score(r) => {
                    let (tx, rx) = mpsc::channel();
                    tx.send(r).unwrap();
                    Ok(Admission::Admitted(Ticket { rx, cancel, id, stream: None }))
                }
                Script::Generate(events, response) => {
                    let (tx, rx) = mpsc::channel();
                    let (stx, srx) = mpsc::channel();
                    for ev in events {
                        stx.send(ev).unwrap();
                    }
                    if let Some(r) = response {
                        tx.send(r).unwrap();
                    }
                    // keep the senders alive past the handler by leaking
                    // them into the ticket's lifetime via a holder thread
                    std::mem::forget(tx);
                    std::mem::forget(stx);
                    Ok(Admission::Admitted(Ticket { rx, cancel, id, stream: Some(srx) }))
                }
                Script::GenerateUntilCancel => {
                    let (tx, rx) = mpsc::channel();
                    let (stx, srx) = mpsc::channel();
                    let flag = cancel.clone();
                    thread::spawn(move || {
                        let mut index = 0usize;
                        while !flag.load(Ordering::Acquire) {
                            if stx.send(StreamEvent::Token { token: 7, index }).is_err() {
                                return;
                            }
                            index += 1;
                            thread::sleep(Duration::from_millis(2));
                        }
                        // serving side observed the cancel between steps
                        let _ = stx.send(StreamEvent::Done {
                            reason: FinishReason::Cancelled,
                            generated: index,
                        });
                        drop(tx);
                    });
                    Ok(Admission::Admitted(Ticket { rx, cancel, id, stream: Some(srx) }))
                }
            }
        }

        fn live_report(&self) -> ServerReport {
            ServerReport::default()
        }

        fn replicas(&self) -> usize {
            1
        }
    }

    fn start(script: Vec<Script>) -> HttpServer {
        let backend = MockBackend::new(script);
        HttpServer::start(backend, HttpConfig::default()).unwrap()
    }

    /// Plain-text HTTP client for tests: send raw bytes, read to EOF.
    fn roundtrip(addr: SocketAddr, raw: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn post(addr: SocketAddr, path: &str, body: &str) -> String {
        roundtrip(
            addr,
            &format!(
                "POST {path} HTTP/1.1\r\nhost: t\r\ncontent-length: {}\r\n\r\n{body}",
                body.len()
            ),
        )
    }

    fn status_of(reply: &str) -> u16 {
        reply
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("no status in {reply:?}"))
    }

    fn body_of(reply: &str) -> &str {
        reply.split("\r\n\r\n").nth(1).unwrap_or("")
    }

    #[test]
    fn healthz_and_metrics_respond() {
        let server = start(vec![]);
        let reply = roundtrip(server.addr(), "GET /healthz HTTP/1.1\r\nhost: t\r\n\r\n");
        assert_eq!(status_of(&reply), 200);
        let j = Json::parse(body_of(&reply)).unwrap();
        assert_eq!(j.req_str("status").unwrap(), "ok");
        assert_eq!(j.req_usize("replicas").unwrap(), 1);
        let reply = roundtrip(server.addr(), "GET /metrics HTTP/1.1\r\nhost: t\r\n\r\n");
        assert_eq!(status_of(&reply), 200);
        assert!(body_of(&reply).contains("mxmoe_http_connections_total"));
        let report = server.shutdown();
        assert_eq!(report.connections, 2);
        assert_eq!(report.disconnects, 0);
    }

    #[test]
    fn status_and_debug_respond_without_an_observatory() {
        // MockBackend keeps the default trait impls (no observatory, no
        // ledger): both pages must still render, with empty sections.
        let server = start(vec![]);
        let reply = roundtrip(server.addr(), "GET /v1/status HTTP/1.1\r\nhost: t\r\n\r\n");
        assert_eq!(status_of(&reply), 200);
        assert!(reply.contains("content-type: application/json"), "{reply}");
        let j = Json::parse(body_of(&reply)).unwrap();
        assert_eq!(j.req_str("version").unwrap(), "mxmoe-status-v1");
        assert_eq!(j.get("series").and_then(Json::as_arr).unwrap().len(), 0);
        assert_eq!(j.get("plans").and_then(Json::as_arr).unwrap().len(), 0);
        // the status page reports the front door's own live counters
        assert!(j.get("report").is_some());
        let reply = roundtrip(server.addr(), "GET /debug HTTP/1.1\r\nhost: t\r\n\r\n");
        assert_eq!(status_of(&reply), 200);
        assert!(reply.contains("content-type: text/html"), "{reply}");
        let body = body_of(&reply);
        assert!(body.starts_with("<!doctype html>"), "{body}");
        assert!(!body.contains("http://") && !body.contains("https://"), "self-contained");
        let raw = "POST /debug HTTP/1.1\r\nhost: t\r\ncontent-length: 0\r\n\r\n";
        let reply = roundtrip(server.addr(), raw);
        assert_eq!(status_of(&reply), 405, "GET-only: {reply}");
        server.shutdown();
    }

    #[test]
    fn score_roundtrip_and_reject_mapping() {
        let server = start(vec![
            Script::Score(resp(42)),
            Script::Reject(RejectReason::QueueFull),
            Script::Reject(RejectReason::KvExhausted),
        ]);
        let reply = post(server.addr(), "/v1/score", r#"{"tokens":[1,2,3]}"#);
        assert_eq!(status_of(&reply), 200);
        let j = Json::parse(body_of(&reply)).unwrap();
        assert_eq!(j.req_usize("next_token").unwrap(), 42);
        assert!(j.req_f64("latency_ms").unwrap() > 0.0);

        let reply = post(server.addr(), "/v1/score", r#"{"tokens":[1]}"#);
        assert_eq!(status_of(&reply), 429, "queue-side shed is 429: {reply}");
        assert!(reply.to_lowercase().contains("retry-after: 2"), "ceil(1.5s)=2: {reply}");
        let j = Json::parse(body_of(&reply)).unwrap();
        assert_eq!(j.req_str("reason").unwrap(), "queue-full");
        assert_eq!(j.req_usize("retry_after_ms").unwrap(), 1500);

        let reply = post(server.addr(), "/v1/generate", r#"{"tokens":[1],"max_new_tokens":4}"#);
        assert_eq!(status_of(&reply), 503, "KV exhaustion is 503: {reply}");
        let j = Json::parse(body_of(&reply)).unwrap();
        assert_eq!(j.req_str("reason").unwrap(), "kv-exhausted");
        server.shutdown();
    }

    #[test]
    fn sse_stream_is_well_formed() {
        let server = start(vec![Script::Generate(
            vec![
                StreamEvent::Token { token: 5, index: 0 },
                StreamEvent::Token { token: 6, index: 1 },
                StreamEvent::Done { reason: FinishReason::Length, generated: 2 },
            ],
            Some(resp(6)),
        )]);
        let reply = post(server.addr(), "/v1/generate", r#"{"tokens":[9],"max_new_tokens":2}"#);
        assert_eq!(status_of(&reply), 200);
        assert!(reply.contains("content-type: text/event-stream"));
        let frames: Vec<&str> = body_of(&reply).split("\n\n").filter(|f| !f.is_empty()).collect();
        assert_eq!(frames.len(), 4, "start + 2 tokens + done: {frames:?}");
        let parse = |frame: &str| {
            let mut lines = frame.lines();
            let ev = lines.next().unwrap().strip_prefix("event: ").unwrap().to_string();
            let data = lines.next().unwrap().strip_prefix("data: ").unwrap().to_string();
            assert!(lines.next().is_none(), "one data line per frame");
            (ev, Json::parse(&data).unwrap())
        };
        let (ev, j) = parse(frames[0]);
        assert_eq!(ev, "start");
        assert!(j.req_usize("id").unwrap() >= 1);
        let (ev, j) = parse(frames[1]);
        assert_eq!((ev.as_str(), j.req_usize("token").unwrap()), ("token", 5));
        assert_eq!(j.req_usize("index").unwrap(), 0);
        let (ev, j) = parse(frames[2]);
        assert_eq!((ev.as_str(), j.req_usize("token").unwrap()), ("token", 6));
        let (ev, j) = parse(frames[3]);
        assert_eq!(ev, "done");
        assert_eq!(j.req_str("reason").unwrap(), "length");
        assert_eq!(j.req_usize("generated").unwrap(), 2);
        assert_eq!(j.get("response").unwrap().req_usize("next_token").unwrap(), 6);
        let report = server.shutdown();
        assert_eq!(report.sse_events, 4);
        assert_eq!(report.disconnects, 0);
    }

    #[test]
    fn cancel_endpoint_flips_the_ticket_and_stream_terminates() {
        let server = start(vec![Script::GenerateUntilCancel]);
        let addr = server.addr();
        let mut s = TcpStream::connect(addr).unwrap();
        let body = r#"{"tokens":[1],"max_new_tokens":100}"#;
        s.write_all(
            format!(
                "POST /v1/generate HTTP/1.1\r\nhost: t\r\ncontent-length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
        // read until the first token frame so the id is known & live
        let mut seen = Vec::new();
        let mut buf = [0u8; 1024];
        while !String::from_utf8_lossy(&seen).contains("event: token") {
            let n = s.read(&mut buf).unwrap();
            assert!(n > 0, "stream closed early");
            seen.extend_from_slice(&buf[..n]);
        }
        let text = String::from_utf8_lossy(&seen).to_string();
        let start_data =
            text.lines().find(|l| l.starts_with("data: ")).unwrap().trim_start_matches("data: ");
        let id = Json::parse(start_data).unwrap().req_usize("id").unwrap();
        let reply = post(addr, &format!("/v1/cancel/{id}"), "{}");
        assert_eq!(status_of(&reply), 200);
        // the stream must now terminate with a cancelled done event
        let mut rest = String::new();
        s.read_to_string(&mut rest).unwrap();
        assert!(rest.contains("event: done"), "terminal frame after cancel: {rest}");
        assert!(rest.contains("\"reason\":\"cancelled\""), "{rest}");
        // the id is gone from the registry now
        let reply = post(addr, &format!("/v1/cancel/{id}"), "{}");
        assert_eq!(status_of(&reply), 404, "finished ids are unknown");
        server.shutdown();
    }

    #[test]
    fn disconnect_mid_stream_cancels_the_ticket() {
        let server = start(vec![Script::GenerateUntilCancel]);
        let addr = server.addr();
        {
            let mut s = TcpStream::connect(addr).unwrap();
            let body = r#"{"tokens":[1],"max_new_tokens":100}"#;
            s.write_all(
                format!(
                    "POST /v1/generate HTTP/1.1\r\nhost: t\r\ncontent-length: {}\r\n\r\n{body}",
                    body.len()
                )
                .as_bytes(),
            )
            .unwrap();
            let mut buf = [0u8; 256];
            let n = s.read(&mut buf).unwrap();
            assert!(n > 0);
            // drop the connection mid-stream
        }
        // the mock keeps streaming tokens, so the handler's next write
        // onto the dead socket fails and flips the cancel flag
        let t0 = std::time::Instant::now();
        let report = loop {
            let r = server.http_report();
            if r.disconnects >= 1 || t0.elapsed() > Duration::from_secs(20) {
                break r;
            }
            thread::sleep(Duration::from_millis(5));
        };
        assert_eq!(report.disconnects, 1, "disconnect observed and counted");
        server.shutdown();
    }

    #[test]
    fn busy_shed_replies_503_with_retry_after() {
        let backend = MockBackend::new(vec![]);
        let cfg = HttpConfig { max_connections: 0, ..HttpConfig::default() };
        let server = HttpServer::start(backend, cfg).unwrap();
        let reply = roundtrip(server.addr(), "GET /healthz HTTP/1.1\r\nhost: t\r\n\r\n");
        assert_eq!(status_of(&reply), 503);
        assert!(reply.to_lowercase().contains("retry-after: 1"), "{reply}");
        let report = server.shutdown();
        assert_eq!(report.rejected_busy, 1);
        assert_eq!(report.connections, 0, "shed connections are not handled ones");
    }

    #[test]
    fn http_trace_spans_record_connections() {
        let backend = MockBackend::new(vec![Script::Score(resp(1))]);
        let cfg = HttpConfig { trace: TraceConfig::on(), ..HttpConfig::default() };
        let server = HttpServer::start(backend, cfg).unwrap();
        let reply = post(server.addr(), "/v1/score", r#"{"tokens":[1]}"#);
        assert_eq!(status_of(&reply), 200);
        let reply = roundtrip(server.addr(), "GET /nope HTTP/1.1\r\nhost: t\r\n\r\n");
        assert_eq!(status_of(&reply), 404);
        // handlers may still be folding their span in; poll briefly
        let t0 = std::time::Instant::now();
        let events = loop {
            let (events, dropped) = server.take_trace();
            assert_eq!(dropped, 0);
            if !events.is_empty() || t0.elapsed() > Duration::from_secs(10) {
                break events;
            }
            thread::sleep(Duration::from_millis(5));
        };
        let score = events
            .iter()
            .find(|e| matches!(e.kind, EventKind::HttpConn { endpoint: "score", .. }))
            .expect("score span recorded");
        assert!(score.req >= 1, "span carries the admission id");
        match score.kind {
            EventKind::HttpConn { status, disconnected, bytes, .. } => {
                assert_eq!(status, 200);
                assert!(!disconnected);
                assert!(bytes > 0);
            }
            _ => unreachable!(),
        }
        server.shutdown();
    }
}
