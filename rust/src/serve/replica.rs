//! Replica workers: N engine threads behind one admission queue
//! (DESIGN.md §Sharded-Serving).
//!
//! The engine (and its PJRT handles) is not `Send`, so that constraint is
//! made *per-replica* instead of global: each worker thread builds and owns
//! its own [`ServingEngine`] — its own PJRT client, its own precision plan,
//! its own telemetry and hot-swap generation counter — and never shares it.
//! What crosses threads is plain data:
//!
//! * [`RoutedBatch`]es flow router → replica through [`WorkQueues`], a
//!   per-replica deque set with work-stealing: a replica drains its own
//!   queue first and otherwise steals the *oldest* batch from the most
//!   backlogged peer, so no replica starves and no batch waits on a busy
//!   replica while another sits idle.
//! * [`ReplicaStatus`] flows replica → router through a status board: the
//!   live scheme table (which changes on hot-swap), the live activation
//!   frequencies, and progress counters — the inputs to the router's
//!   expert-affinity scoring.
//!
//! Telemetry, drift detection and replanning are per-replica: every worker
//! runs its own telemetry → drift → re-solve → hot-swap loop between
//! batches, so under online serving the replicas' plans can diverge to
//! match the slices of traffic they actually see.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::alloc::Allocation;
use crate::coordinator::engine::{ReplanStaging, ServingEngine};
use crate::coordinator::metrics::{ReplicaReport, SloClassStats, SLO_CLASSES};
use crate::moe::{ModelConfig, MoeLm};
use crate::obs::{
    Deadline, EventKind, Outcome, ProvenanceLedger, SpanCollector, TraceClock, TraceConfig, Track,
};
use crate::runtime::RuntimeScheme;
use crate::ser::MxtFile;
use crate::serve::decode::{DecodePolicy, DecodeScheduler};
use crate::serve::queue::{Request, Response, ShedInfo};
use crate::serve::replan::Replanner;
use crate::serve::request::AdmissionState;

/// One batch as cut by the router: the unit of work routed to (and stolen
/// between) replicas.
pub struct RoutedBatch {
    pub requests: Vec<Request>,
}

impl RoutedBatch {
    pub fn tokens(&self) -> usize {
        self.requests.iter().map(|r| r.tokens.len()).sum()
    }

    /// Drop cancelled requests before execution; returns what was shed
    /// (ids included, so the shed is per-request attributable in the
    /// trace). Cancellation propagates here through [`WorkQueues`]: a
    /// batch that was routed (or stolen) after its requests were cancelled
    /// sheds the dead entries at the pop instead of executing them.
    pub fn shed_cancelled(&mut self) -> Vec<ShedInfo> {
        let now = Instant::now();
        let mut shed = Vec::new();
        self.requests.retain(|r| {
            if r.is_cancelled() {
                shed.push(ShedInfo {
                    id: r.id,
                    tokens: r.tokens.len(),
                    queued: now.saturating_duration_since(r.arrived),
                    qos: r.qos.map_or("none", |q| q.name()),
                });
                false
            } else {
                true
            }
        });
        shed
    }
}

/// Per-replica work deques with work-stealing.
///
/// Push side is the router (affinity-chosen replica); pop side is the
/// replicas themselves. [`pop`](WorkQueues::pop) blocks until work or
/// shutdown: a replica takes from its own deque front first and otherwise
/// steals the front (oldest) batch of the deepest peer deque — FIFO
/// fairness survives stealing, and an idle replica always makes progress
/// on the cluster backlog.
pub struct WorkQueues {
    inner: Mutex<QueuesInner>,
    available: Condvar,
}

struct QueuesInner {
    queues: Vec<VecDeque<RoutedBatch>>,
    /// Batches popped but not yet reported done — what keeps the router's
    /// load signal honest about work that already left the deques.
    inflight: Vec<usize>,
    /// Pending + active generations on each replica's decode scheduler —
    /// the decode loop's contribution to the router's load signal.
    /// Deliberately *not* part of the capacity wait: a decoding replica
    /// merges newly routed work into its next step, so it still counts as
    /// available capacity.
    decode: Vec<usize>,
    /// Replicas that died before serving (engine build failure). Their
    /// queued batches are stolen by the living; they never count as
    /// capacity.
    dead: Vec<bool>,
    /// Kill requests ([`WorkQueues::request_kill`]): the replica's pop
    /// flavors stop handing out work so its main loop notices promptly,
    /// fails what its decode scheduler still holds, and marks itself
    /// dead. Cleared by [`WorkQueues::revive`] on restart.
    kill: Vec<bool>,
    closed: bool,
}

/// Result of a non-blocking [`WorkQueues::try_pop`].
pub enum TryPop {
    /// A batch (own deque or stolen — the flag mirrors [`WorkQueues::pop`]).
    Batch(RoutedBatch, bool),
    /// Nothing queued anywhere right now.
    Empty,
    /// Queues closed and fully drained.
    Closed,
}

impl WorkQueues {
    pub fn new(replicas: usize) -> Arc<WorkQueues> {
        assert!(replicas >= 1);
        Arc::new(WorkQueues {
            inner: Mutex::new(QueuesInner {
                queues: (0..replicas).map(|_| VecDeque::new()).collect(),
                inflight: vec![0; replicas],
                decode: vec![0; replicas],
                dead: vec![false; replicas],
                kill: vec![false; replicas],
                closed: false,
            }),
            available: Condvar::new(),
        })
    }

    pub fn replicas(&self) -> usize {
        self.inner.lock().unwrap().queues.len()
    }

    /// Enqueue a batch for `replica` (router side).
    pub fn push(&self, replica: usize, batch: RoutedBatch) {
        let mut g = self.inner.lock().unwrap();
        assert!(!g.closed, "push after close");
        g.queues[replica].push_back(batch);
        drop(g);
        self.available.notify_all();
    }

    /// One non-blocking take under the lock: own deque front first,
    /// otherwise steal the oldest batch of the most backlogged peer. The
    /// single home of the take/steal policy — every pop flavor goes
    /// through here, so they cannot drift apart.
    fn take_locked(g: &mut QueuesInner, replica: usize) -> Option<(RoutedBatch, bool)> {
        if let Some(b) = g.queues[replica].pop_front() {
            g.inflight[replica] += 1;
            return Some((b, false));
        }
        let victim = (0..g.queues.len())
            .filter(|&i| i != replica && !g.queues[i].is_empty())
            .max_by_key(|&i| g.queues[i].len());
        victim.map(|v| {
            let b = g.queues[v].pop_front().unwrap();
            g.inflight[replica] += 1;
            (b, true)
        })
    }

    /// Dequeue the next batch for `replica`, blocking until one is
    /// available or the queues are closed *and* fully drained. Returns the
    /// batch plus whether it was stolen from a peer. The popped batch
    /// counts as in-flight for `replica` until [`done`](WorkQueues::done).
    pub fn pop(&self, replica: usize) -> Option<(RoutedBatch, bool)> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.kill[replica] {
                return None; // killed: stop handing this replica work
            }
            if let Some(got) = WorkQueues::take_locked(&mut g, replica) {
                return Some(got);
            }
            if g.closed {
                return None;
            }
            g = self.available.wait(g).unwrap();
        }
    }

    /// Non-blocking pop for a replica whose decode loop is mid-generation:
    /// same own-queue-then-steal policy as [`pop`](WorkQueues::pop), but
    /// never waits — the caller has decode steps to run. A returned batch
    /// counts as in-flight until [`done`](WorkQueues::done).
    pub fn try_pop(&self, replica: usize) -> TryPop {
        let mut g = self.inner.lock().unwrap();
        if g.kill[replica] {
            return TryPop::Closed; // killed: stop handing this replica work
        }
        match WorkQueues::take_locked(&mut g, replica) {
            Some((b, stolen)) => TryPop::Batch(b, stolen),
            None if g.closed => TryPop::Closed,
            None => TryPop::Empty,
        }
    }

    /// As [`pop`](WorkQueues::pop) but gives up after `timeout` when
    /// nothing arrives (`TryPop::Empty`). What an otherwise-idle replica
    /// with a hot-swap staging in flight waits with, so a plan staged
    /// during the tail of a burst is still flipped promptly instead of
    /// sitting until the next arrival.
    pub fn pop_timeout(&self, replica: usize, timeout: Duration) -> TryPop {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.kill[replica] {
                return TryPop::Closed; // killed: stop handing this replica work
            }
            if let Some((b, stolen)) = WorkQueues::take_locked(&mut g, replica) {
                return TryPop::Batch(b, stolen);
            }
            if g.closed {
                return TryPop::Closed;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return TryPop::Empty;
            }
            let (guard, _timeout) = self.available.wait_timeout(g, left).unwrap();
            g = guard;
        }
    }

    /// Publish `replica`'s decode-scheduler load (pending + active
    /// generations) into the router's load signal.
    pub fn note_decode_load(&self, replica: usize, seqs: usize) {
        self.inner.lock().unwrap().decode[replica] = seqs;
    }

    /// Mark the batch last popped by `replica` as executed. Wakes capacity
    /// waiters: a completed batch is what frees a replica.
    pub fn done(&self, replica: usize) {
        let mut g = self.inner.lock().unwrap();
        g.inflight[replica] = g.inflight[replica].saturating_sub(1);
        drop(g);
        self.available.notify_all();
    }

    /// Mark `replica` as permanently unable to serve (engine build
    /// failure). Its queued batches remain stealable; capacity waiters are
    /// woken so the router can notice a fully-dead cluster.
    pub fn mark_dead(&self, replica: usize) {
        self.inner.lock().unwrap().dead[replica] = true;
        self.available.notify_all();
    }

    /// Ask `replica`'s worker to stop serving (mid-run kill — the scenario
    /// engine's replica-flap hook). Its pop flavors stop handing out work,
    /// so a blocked worker wakes immediately; the worker's main loop then
    /// fails its outstanding generations through the normal accounting and
    /// marks itself dead. Queued batches stay stealable by the survivors.
    pub fn request_kill(&self, replica: usize) {
        self.inner.lock().unwrap().kill[replica] = true;
        self.available.notify_all();
    }

    /// True once [`request_kill`](Self::request_kill) was called for
    /// `replica` (and not yet cleared by [`revive`](Self::revive)).
    pub fn kill_requested(&self, replica: usize) -> bool {
        self.inner.lock().unwrap().kill[replica]
    }

    /// Clear `replica`'s dead and kill flags and reset its load counters —
    /// the restart path, called before a fresh worker thread is spawned
    /// under the same id. Batches still queued for the replica are kept;
    /// the respawned worker drains them.
    pub fn revive(&self, replica: usize) {
        let mut g = self.inner.lock().unwrap();
        g.dead[replica] = false;
        g.kill[replica] = false;
        g.inflight[replica] = 0;
        g.decode[replica] = 0;
        drop(g);
        self.available.notify_all();
    }

    /// Block until some live replica is idle (nothing queued, nothing in
    /// flight), so a batch cut now can start executing immediately —
    /// the cluster generalization of the legacy single-engine loop, which
    /// only ever cut strictly between batches. Returns `false` when every
    /// replica is dead (no batch can ever execute); returns `true`
    /// immediately on close so a draining caller is never wedged.
    pub fn wait_for_capacity(&self) -> bool {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.dead.iter().all(|&d| d) {
                return false;
            }
            let idle = (0..g.queues.len())
                .any(|i| !g.dead[i] && g.queues[i].is_empty() && g.inflight[i] == 0);
            if idle || g.closed {
                return true;
            }
            g = self.available.wait(g).unwrap();
        }
    }

    /// No more pushes: blocked `pop`s return `None` once drained.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.available.notify_all();
    }

    /// Queued batches per replica.
    pub fn depths(&self) -> Vec<usize> {
        self.inner.lock().unwrap().queues.iter().map(|q| q.len()).collect()
    }

    pub fn depth(&self, replica: usize) -> usize {
        self.inner.lock().unwrap().queues[replica].len()
    }

    /// Queued + in-flight batches + decode-scheduler sequences per
    /// replica — the router's backlog signal. Counting in-flight work is
    /// what stops the router from piling batches onto a replica whose
    /// deque merely *looks* empty because it popped everything into
    /// execution; counting decode sequences steers new work away from
    /// replicas mid-generation.
    pub fn loads(&self) -> Vec<usize> {
        let g = self.inner.lock().unwrap();
        g.queues
            .iter()
            .zip(&g.inflight)
            .zip(&g.decode)
            .map(|((q, &f), &d)| q.len() + f + d)
            .collect()
    }
}

/// What a replica publishes for the router's affinity scoring: the live
/// plan (scheme per slot), the live activation-frequency estimate, and
/// progress counters. Seeded from the boot allocation before the replica's
/// engine finishes building, so the router can score from the first cut.
#[derive(Clone, Debug)]
pub struct ReplicaStatus {
    /// Hot-swap generation of the published scheme table.
    pub generation: u64,
    /// Runtime family per `[block_pos][expert slot]` (routed then shared).
    pub schemes: Vec<Vec<RuntimeScheme>>,
    /// Live per-layer routed-expert frequency estimate (EWMA).
    pub live_freqs: Vec<Vec<f64>>,
    /// Routed token-assignments this replica has observed (weighs its
    /// frequency estimate in the cluster aggregate).
    pub observed_tokens: usize,
    /// Batches this replica has executed.
    pub batches_done: usize,
    pub swaps: usize,
    pub replans: usize,
    /// Requests served per [`crate::serve::QosClass`] (requests without a
    /// class count as `Standard`) — the cluster-level view of what QoS mix
    /// each replica's plan is serving.
    pub qos_served: [usize; 3],
    /// Measured grouped-dispatch wave work per runtime family:
    /// `(scheme, useful_rows, busy_s)` — the router's input for measured
    /// affinity speeds ([`crate::coordinator::cluster::measured_speeds`]).
    pub scheme_rows: Vec<(RuntimeScheme, usize, f64)>,
    /// Generations on this replica's decode scheduler (pending + active).
    pub decode_seqs: usize,
    /// Tokens generated and streamed so far.
    pub generated_tokens: usize,
    /// Scoring requests this replica has answered (live counter for the
    /// HTTP front door's `/metrics` scrape — reports otherwise only exist
    /// at shutdown).
    pub requests_done: usize,
    /// Prompt tokens processed by answered requests.
    pub tokens_done: usize,
    /// Generations completed (stop-token or length).
    pub generations_done: usize,
    /// Generations preempted for KV pages and replayed.
    pub kv_preemptions: usize,
    /// Unclaimed tokens under the decode KV page budget (0 until the
    /// replica publishes — the front door's KV backpressure gate only
    /// engages once `kv_budget_tokens > 0`).
    pub kv_free_tokens: usize,
    /// The replica's KV page-pool budget in tokens.
    pub kv_budget_tokens: usize,
    /// Positions per KV page (lazy admission claims round up to this).
    pub kv_page_size: usize,
    /// EWMA KV page-release rate, tokens/second (0 until warmed) — what
    /// `retry_after` is derived from when the pool is the bottleneck.
    pub kv_release_tps: f64,
    /// KV tokens currently reserved by live generations.
    pub kv_used_tokens: usize,
    /// KV tokens served from shared prefix pages (counted once).
    pub kv_shared_tokens: usize,
    /// Live average KV-cache bits/value across resident sequences (32.0
    /// when the pool is empty — fp32 reference, never NaN).
    pub kv_avg_bits: f64,
    /// Live per-QoS-class SLO accounting — what the cluster sampler reads
    /// for longitudinal hit-rate series (reports otherwise only exist at
    /// shutdown).
    pub slo: [SloClassStats; SLO_CLASSES],
}

impl ReplicaStatus {
    /// Status derived from the boot allocation alone — what the router
    /// scores against until the replica publishes its first live update.
    pub fn boot(cfg: &ModelConfig, allocation: &Allocation) -> ReplicaStatus {
        let schemes: Vec<Vec<RuntimeScheme>> = allocation
            .schemes
            .iter()
            .map(|layer| layer.iter().map(|s| RuntimeScheme::from_quant(&s[0])).collect())
            .collect();
        let n = cfg.n_experts.max(1);
        ReplicaStatus {
            generation: 0,
            live_freqs: vec![vec![1.0 / n as f64; n]; schemes.len()],
            schemes,
            observed_tokens: 0,
            batches_done: 0,
            swaps: 0,
            replans: 0,
            qos_served: [0; 3],
            scheme_rows: Vec::new(),
            decode_seqs: 0,
            generated_tokens: 0,
            requests_done: 0,
            tokens_done: 0,
            generations_done: 0,
            kv_preemptions: 0,
            kv_free_tokens: 0,
            kv_budget_tokens: 0,
            kv_page_size: 0,
            kv_release_tps: 0.0,
            kv_used_tokens: 0,
            kv_shared_tokens: 0,
            kv_avg_bits: 32.0,
            slo: [SloClassStats::default(); SLO_CLASSES],
        }
    }
}

/// Per-replica online-serving inputs, shared read-only across replicas.
pub struct ReplicaOnline {
    pub replanner: Replanner,
    /// Calibration frequency baseline seeding each replica's drift
    /// detector.
    pub baseline: Vec<Vec<f64>>,
    pub ewma_alpha: Option<f64>,
}

/// Everything a replica thread needs to build and run its engine. All
/// fields are `Send`; the non-`Send` engine is constructed inside the
/// thread.
pub struct ReplicaSpec {
    pub id: usize,
    pub cfg: ModelConfig,
    /// Weights, loaded once by the cluster and shared — each replica builds
    /// its own model (and quantizes its own expert slots) from them.
    pub weights: Arc<MxtFile>,
    pub artifacts: PathBuf,
    pub allocation: Allocation,
    pub online: Option<Arc<ReplicaOnline>>,
    /// Grouped-dispatch worker threads per replica (`None` = engine
    /// default).
    pub dispatch_threads: Option<usize>,
    /// Decode-loop sizing (step row budget, active-sequence cap, KV
    /// reservation budget).
    pub decode: DecodePolicy,
    /// Cluster-shared trace clock: all tracks stamp microseconds from the
    /// same origin, so replica spans line up with admission/router spans.
    pub clock: TraceClock,
    /// Lifecycle-span tracing switch + ring capacity for this replica.
    pub trace: TraceConfig,
    /// Cluster-shared plan-provenance ledger: the replica records a boot
    /// plan on engine build and every replan/hot-swap decision thereafter
    /// (`None` = provenance off, zero work on the replan path).
    pub provenance: Option<Arc<ProvenanceLedger>>,
}

/// Replica thread body: build the engine (own PJRT client, own plan), then
/// serve until the queues close. `admission` carries cancellation
/// accounting back to the front door and feeds the service-rate estimate
/// its load-shedding projections run on.
///
/// Since the decode redesign (DESIGN.md §Decode-Loop) the loop runs at two
/// granularities: scoring batches execute whole (the legacy path), while
/// generation requests join the replica's [`DecodeScheduler`] and advance
/// one *step* per loop turn. A replica with live generations never blocks
/// on its deque — it takes at most one queued batch per turn without
/// waiting ([`WorkQueues::try_pop`]) and keeps stepping, so freshly routed
/// work merges into the next mixed prefill/decode batch and a sustained
/// scoring stream cannot starve decode. Hot-swap staging is polled between
/// turns: the re-quantization runs on a worker thread ([`ReplanStaging`]),
/// only the generation-counted flip happens here, and an idle replica
/// waits with a *bounded* pop ([`WorkQueues::pop_timeout`]) while a
/// staging is in flight so a finished swap is installed promptly.
pub fn replica_main(
    spec: ReplicaSpec,
    queues: Arc<WorkQueues>,
    status: Arc<Vec<Mutex<ReplicaStatus>>>,
    admission: Arc<AdmissionState>,
) -> ReplicaReport {
    // a boot failure marks this replica dead first, so the router's
    // capacity wait skips it (and gives up entirely if nothing survives)
    // instead of waiting forever on a thread that will never pop
    let lm = MoeLm::load_mxt(&spec.cfg, &spec.weights).unwrap_or_else(|e| {
        queues.mark_dead(spec.id);
        panic!("replica {}: build model: {e:#}", spec.id)
    });
    let mut engine =
        ServingEngine::new(lm, &spec.artifacts, &spec.allocation).unwrap_or_else(|e| {
            queues.mark_dead(spec.id);
            panic!("replica {}: build engine: {e:#}", spec.id)
        });
    if let Some(t) = spec.dispatch_threads {
        engine.set_dispatch_threads(t);
    }
    // this replica's span ring: owned by its metrics, stamped on the
    // cluster-shared clock, drained once into the report at thread exit
    engine.metrics_mut().set_tracer(SpanCollector::new(
        spec.clock.clone(),
        Track::Replica(spec.id),
        spec.trace,
    ));
    if let Some(online) = &spec.online {
        engine.set_baseline(online.baseline.clone());
        if let Some(a) = online.ewma_alpha {
            engine.set_telemetry_alpha(a);
        }
    }
    if let Some(ledger) = &spec.provenance {
        engine.set_provenance(Arc::clone(ledger), spec.id);
        // Record the boot plan so "why does (l,e) run at this scheme?" has
        // an answer before the first replan. Offline replicas have no
        // replanner (no sensitivity, no QoS blend) — record structure only.
        let (sens, r) = match &spec.online {
            Some(o) => (Some(&o.replanner.sens), o.replanner.cfg.alloc.r),
            None => (None, 0.5),
        };
        engine.record_boot_provenance(sens, r);
    }
    let mut decoder = DecodeScheduler::new(&spec.cfg, spec.decode.clone());
    let mut staging: Option<ReplanStaging> = None;
    let mut published_gen = publish(&spec, &engine, &decoder, &status, 0, None);
    let mut batches_done = 0usize;
    let mut stolen = 0usize;
    loop {
        // ---- kill hook (scenario replica-flap): stop taking work, fail
        // everything the decode scheduler still holds through the normal
        // accounting (admitted == responses + cancelled + failed stays
        // exact), and mark this replica dead — its queued batches stay
        // stealable and a later revive + respawn restarts service ----
        if queues.kill_requested(spec.id) {
            let evicted = decoder.evict_all();
            admission.note_failed(evicted.len());
            let tracer = engine.metrics_mut().tracer();
            for r in &evicted {
                trace_terminal(tracer, r, Outcome::Failed);
            }
            queues.note_decode_load(spec.id, 0);
            queues.mark_dead(spec.id);
            break;
        }
        // ---- acquire work: block only when the decode loop is idle AND
        // no staged swap is waiting. Mid-generation the pop is
        // non-blocking and bounded to one batch per turn, so a sustained
        // scoring stream interleaves with decode steps instead of
        // starving them; with a staging in flight the wait is bounded so
        // an idle replica still flips the plan promptly ----
        if decoder.has_work() {
            match queues.try_pop(spec.id) {
                TryPop::Batch(batch, was_stolen) => {
                    if was_stolen {
                        stolen += 1;
                    }
                    batches_done += 1;
                    handle_batch(&mut engine, &mut decoder, &queues, &admission, spec.id, batch);
                }
                TryPop::Empty | TryPop::Closed => {}
            }
        } else if staging.is_some() {
            match queues.pop_timeout(spec.id, Duration::from_millis(5)) {
                TryPop::Batch(batch, was_stolen) => {
                    if was_stolen {
                        stolen += 1;
                    }
                    batches_done += 1;
                    handle_batch(&mut engine, &mut decoder, &queues, &admission, spec.id, batch);
                }
                TryPop::Empty => {} // fall through to the staging poll
                TryPop::Closed if queues.kill_requested(spec.id) => continue, // kill hook runs
                TryPop::Closed => break,
            }
        } else {
            match queues.pop(spec.id) {
                Some((batch, was_stolen)) => {
                    if was_stolen {
                        stolen += 1;
                    }
                    batches_done += 1;
                    handle_batch(&mut engine, &mut decoder, &queues, &admission, spec.id, batch);
                }
                // a kill wakes the blocked pop: loop back so the kill hook
                // at the top runs (mark dead, fail decode work)
                None if queues.kill_requested(spec.id) => continue,
                None => break, // closed, drained, and no generation in flight
            }
        }
        // ---- one decode step between pops: mixed prefill chunks +
        // single-token decode rows, cut against the tile budget ----
        if decoder.has_work() {
            run_decode_step(&mut engine, &mut decoder, &admission);
        }
        queues.note_decode_load(spec.id, decoder.load());
        // ---- online loop strictly between batches/steps: flip a staged
        // swap when the worker is done, begin a new staging on drift ----
        if let Some(online) = &spec.online {
            if staging.as_ref().map_or(false, |s| s.finished()) {
                let st = staging.take().unwrap();
                match engine.finish_replan(st) {
                    Ok(outcome) => eprintln!(
                        "replica {}: replan drift {:.3} → {} slot(s) changed, {} swapped (gen {})",
                        spec.id,
                        outcome.drift,
                        outcome.changes,
                        outcome.swapped,
                        engine.generation()
                    ),
                    Err(e) => eprintln!(
                        "replica {}: replan failed (serving continues on old plan): {e:#}",
                        spec.id
                    ),
                }
            }
            if staging.is_none() {
                match engine.maybe_begin_replan(&online.replanner) {
                    Ok(Some(st)) => staging = Some(st),
                    Ok(None) => {}
                    Err(e) => eprintln!(
                        "replica {}: replan solve failed (serving continues): {e:#}",
                        spec.id
                    ),
                }
            }
        }
        published_gen = publish(&spec, &engine, &decoder, &status, batches_done, Some(published_gen));
    }
    // join a straggling staging worker so it is never leaked; applying it
    // at shutdown is harmless (nothing serves afterwards)
    if let Some(st) = staging.take() {
        if let Err(e) = engine.finish_replan(st) {
            eprintln!("replica {}: shutdown replan join failed: {e:#}", spec.id);
        }
    }
    collect_report(&spec, &mut engine, batches_done, stolen)
}

/// Handle one popped batch: shed cancellations, route generations into the
/// decode scheduler, execute the scoring remainder as one whole-sequence
/// forward (the legacy path, bit-identical batch composition).
fn handle_batch(
    engine: &mut ServingEngine,
    decoder: &mut DecodeScheduler,
    queues: &WorkQueues,
    admission: &AdmissionState,
    replica: usize,
    mut batch: RoutedBatch,
) {
    // cancellation propagated through the deques: dead entries are shed
    // here instead of executing, whether the batch was routed to this
    // replica or stolen from a peer
    let shed = batch.shed_cancelled();
    if !shed.is_empty() {
        admission.note_cancelled(shed.len());
        let m = engine.metrics_mut();
        m.shed_cancelled += shed.len();
        for s in &shed {
            m.tracer().instant(
                s.id,
                EventKind::Terminal {
                    outcome: Outcome::Cancelled,
                    qos: s.qos,
                    queue_us: s.queued.as_micros() as u64,
                    compute_us: 0,
                    stream_us: 0,
                    generation: 0,
                    deadline: Deadline::None,
                    tokens: s.tokens,
                },
            );
        }
    }
    if batch.requests.is_empty() {
        queues.done(replica);
        return;
    }
    engine.metrics_mut().note_queue_depth(queues.depth(replica));
    let mut scoring = Vec::with_capacity(batch.requests.len());
    for r in batch.requests.drain(..) {
        if r.kind.is_generate() {
            decoder.admit(r);
        } else {
            scoring.push(r);
        }
    }
    if !scoring.is_empty() {
        let scoring_batch = RoutedBatch { requests: scoring };
        let batch_tokens = scoring_batch.tokens();
        let exec_started = Instant::now();
        let (suppressed, failed) = process_batch(engine, scoring_batch);
        admission.note_service(batch_tokens, exec_started.elapsed());
        if suppressed > 0 {
            // cancelled after the cut raced execution: the work ran, but
            // no response was produced — still counts as cancelled
            admission.note_cancelled(suppressed);
        }
        // a failed forward produced no replies: account for the whole
        // batch so admitted == responses + cancelled + failed stays exact
        admission.note_failed(failed);
    }
    queues.done(replica);
}

/// Run one decode step and account for everything it did: service-rate
/// samples, decode metrics, terminal replies (suppressed for cancelled
/// tickets), and the cancellation/failure bookkeeping that keeps
/// `admitted == responses + cancelled + failed` exact.
fn run_decode_step(
    engine: &mut ServingEngine,
    decoder: &mut DecodeScheduler,
    admission: &AdmissionState,
) {
    // keep the prefix-share map keyed to the live plan generation: a
    // hot-swap invalidates sealed pages for new prefills
    decoder.set_share_epoch(engine.generation());
    let t0 = Instant::now();
    let outcome = decoder.step(|inputs| engine.forward_step_batch(inputs));
    let elapsed = t0.elapsed();
    if outcome.rows > 0 {
        admission.note_service(outcome.rows, elapsed);
        if let Some(est) = outcome.fill {
            engine.metrics_mut().note_planned_fill(est.fill_ratio());
        }
        engine.metrics_mut().record_decode_step(
            outcome.prefill_rows,
            outcome.decode_rows,
            outcome.tokens_emitted,
            outcome.finished.len(),
            elapsed.as_secs_f64(),
        );
        let occ = decoder.occupancy();
        let tracer = engine.metrics_mut().tracer();
        if tracer.enabled() {
            let dur_us = elapsed.as_micros() as u64;
            tracer.span(
                tracer.now_us().saturating_sub(dur_us),
                dur_us,
                0,
                EventKind::DecodeStep {
                    rows: outcome.rows,
                    prefill_rows: outcome.prefill_rows,
                    decode_rows: outcome.decode_rows,
                    tokens: outcome.tokens_emitted,
                    kv_reserved: occ.reserved_tokens,
                    kv_used: occ.used_tokens,
                    kv_budget: occ.budget_tokens,
                },
            );
        }
    }
    if !outcome.preempted.is_empty() {
        let occ = decoder.occupancy();
        let metrics = engine.metrics_mut();
        metrics.record_kv_preemptions(outcome.preempted.len());
        let tracer = metrics.tracer();
        for &id in &outcome.preempted {
            tracer.instant(
                id,
                EventKind::KvPreempt {
                    kv_reserved: occ.reserved_tokens,
                    kv_budget: occ.budget_tokens,
                },
            );
        }
    }
    admission.note_cancelled(outcome.cancelled.len());
    admission.note_failed(outcome.failed.len());
    {
        let tracer = engine.metrics_mut().tracer();
        for r in &outcome.cancelled {
            trace_terminal(tracer, r, Outcome::Cancelled);
        }
        for r in &outcome.failed {
            trace_terminal(tracer, r, Outcome::Failed);
        }
    }
    let generation = engine.generation();
    let mut late_cancels = 0usize;
    for fin in outcome.finished {
        if fin.request.is_cancelled() {
            // cancelled in the same step it finished: the work ran, but a
            // cancelled ticket never yields a response
            late_cancels += 1;
            trace_terminal(engine.metrics_mut().tracer(), &fin.request, Outcome::Cancelled);
            continue;
        }
        let now = Instant::now();
        let latency = now.saturating_duration_since(fin.request.arrived);
        let deadline = deadline_verdict(fin.request.deadline, now);
        let metrics = engine.metrics_mut();
        metrics.record_request(latency.as_secs_f64(), fin.request.tokens.len() + fin.generated);
        metrics.record_class_latency(fin.request.qos, latency.as_secs_f64());
        metrics.record_queue_wait(fin.queue_wait.as_secs_f64(), fin.request.priority);
        metrics.note_qos(fin.request.qos);
        metrics.note_slo(
            fin.request.qos,
            deadline,
            fin.queue_wait.as_secs_f64(),
            fin.compute.as_secs_f64(),
            fin.stream.as_secs_f64(),
            generation,
        );
        metrics.tracer().instant(
            fin.request.id,
            EventKind::Terminal {
                outcome: Outcome::Done,
                qos: fin.request.qos.map_or("none", |q| q.name()),
                queue_us: fin.queue_wait.as_micros() as u64,
                compute_us: fin.compute.as_micros() as u64,
                stream_us: fin.stream.as_micros() as u64,
                generation,
                deadline,
                tokens: fin.request.tokens.len() + fin.generated,
            },
        );
        let _ = fin.request.reply.send(Response {
            next_token: fin.last_token.unwrap_or(0),
            mean_nll: fin.mean_prompt_nll,
            latency,
            queue_wait: fin.queue_wait,
            generation,
        });
    }
    admission.note_cancelled(late_cancels);
    engine.metrics_mut().note_kv_occupancy(&decoder.occupancy());
}

/// Deadline verdict for a request finishing at `now`. `Deadline::None`
/// when the request carried no deadline.
fn deadline_verdict(deadline: Option<Instant>, now: Instant) -> Deadline {
    match deadline {
        None => Deadline::None,
        Some(d) if now <= d => Deadline::Hit,
        Some(_) => Deadline::Miss,
    }
}

/// Record the terminal span for a request that produced no response
/// (cancelled or failed): zero compute/stream time, queue time = its whole
/// lifetime so far. Every exit path records exactly one terminal per
/// admitted request — the invariant the trace accounting tests restate.
fn trace_terminal(tracer: &mut SpanCollector, req: &Request, outcome: Outcome) {
    tracer.instant(
        req.id,
        EventKind::Terminal {
            outcome,
            qos: req.qos.map_or("none", |q| q.name()),
            queue_us: req.arrived.elapsed().as_micros() as u64,
            compute_us: 0,
            stream_us: 0,
            generation: 0,
            deadline: Deadline::None,
            tokens: req.tokens.len(),
        },
    );
}

/// Publish this replica's live state to the status board. The scheme table
/// is only re-cloned when the generation moved (hot-swap); frequencies and
/// counters refresh every batch.
fn publish(
    spec: &ReplicaSpec,
    engine: &ServingEngine,
    decoder: &DecodeScheduler,
    status: &[Mutex<ReplicaStatus>],
    batches_done: usize,
    published_gen: Option<u64>,
) -> u64 {
    let generation = engine.generation();
    let mut s = status[spec.id].lock().unwrap();
    if published_gen != Some(generation) {
        s.schemes = engine.plan_schemes();
        s.generation = generation;
    }
    s.live_freqs = engine.telemetry().live().to_vec();
    s.observed_tokens = engine.telemetry().observed_tokens;
    s.batches_done = batches_done;
    s.swaps = engine.metrics().swaps;
    s.replans = engine.metrics().replans;
    s.qos_served = engine.metrics().qos_served;
    s.scheme_rows = measured_scheme_rows(engine);
    s.decode_seqs = decoder.load();
    s.generated_tokens = engine.metrics().generated_tokens;
    s.requests_done = engine.metrics().requests;
    s.tokens_done = engine.metrics().tokens;
    s.generations_done = engine.metrics().generations;
    s.kv_preemptions = engine.metrics().kv_preemptions;
    let occ = decoder.occupancy();
    s.kv_free_tokens = decoder.free_kv_tokens();
    s.kv_budget_tokens = occ.budget_tokens;
    s.kv_page_size = decoder.kv_page_size();
    s.kv_release_tps = decoder.kv_release_tps();
    s.kv_used_tokens = occ.used_tokens;
    s.kv_shared_tokens = occ.shared_tokens;
    s.kv_avg_bits = occ.avg_kv_bits;
    s.slo = engine.metrics().slo;
    generation
}

/// `(scheme, useful_rows, busy_s)` per runtime family from the engine's
/// grouped-dispatch wave counters — the raw material for measured affinity
/// speeds. Families that have executed no waves are omitted.
fn measured_scheme_rows(engine: &ServingEngine) -> Vec<(RuntimeScheme, usize, f64)> {
    let stats = engine.metrics().scheme_wave_stats();
    RuntimeScheme::ALL
        .iter()
        .filter_map(|&s| {
            stats
                .get(s.name())
                .filter(|w| w.useful_rows > 0 && w.busy_s > 0.0)
                .map(|w| (s, w.useful_rows, w.busy_s))
        })
        .collect()
}

/// Execute one batch and reply per request: argmax continuation + mean
/// next-token NLL, stamped with the generation that served it. Queue wait
/// is measured admission → execution start, matching the legacy
/// single-engine loop (which cut immediately before executing) — deque
/// time counts as queueing, not as serving.
///
/// Returns `(suppressed, failed)` — the requests that got no reply:
/// `suppressed` are late cancels (the request executed — its rows were
/// already in the concatenated forward — but the response is withheld so
/// a cancelled ticket never yields one); `failed` is the whole batch when
/// the forward pass errors. Both feed the admission accounting, so
/// `admitted == responses + cancelled + failed` stays exact.
pub fn process_batch(engine: &mut ServingEngine, batch: RoutedBatch) -> (usize, usize) {
    let RoutedBatch { requests } = batch;
    let exec_at = Instant::now();
    let generation = engine.generation();
    let mut suppressed = 0usize;
    let seqs: Vec<&[u32]> = requests.iter().map(|r| r.tokens.as_slice()).collect();
    match engine.forward_batch(&seqs) {
        Ok(logits_batch) => {
            for (req, logits) in requests.iter().zip(logits_batch) {
                if req.is_cancelled() {
                    suppressed += 1;
                    trace_terminal(engine.metrics_mut().tracer(), req, Outcome::Cancelled);
                    continue;
                }
                let t = req.tokens.len();
                // argmax of the final position
                let last = logits.row(t - 1);
                let mut best = 0usize;
                for i in 1..last.len() {
                    if last[i] > last[best] {
                        best = i;
                    }
                }
                // mean next-token NLL
                let mut nll = 0.0f64;
                for pos in 0..t - 1 {
                    let row = logits.row(pos);
                    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
                    let z: f64 = row.iter().map(|&v| ((v as f64) - m).exp()).sum();
                    nll -= (logits.at(pos, req.tokens[pos + 1] as usize) as f64 - m) - z.ln();
                }
                let now = Instant::now();
                let latency = now.saturating_duration_since(req.arrived);
                let queue_wait = exec_at.saturating_duration_since(req.arrived);
                // scoring replies whole-batch: compute spans execution
                // start → reply, and nothing streams before the reply
                let compute = now.saturating_duration_since(exec_at);
                let deadline = deadline_verdict(req.deadline, now);
                let metrics = engine.metrics_mut();
                metrics.record_request(latency.as_secs_f64(), req.tokens.len());
                metrics.record_class_latency(req.qos, latency.as_secs_f64());
                metrics.record_queue_wait(queue_wait.as_secs_f64(), req.priority);
                metrics.note_qos(req.qos);
                metrics.note_slo(
                    req.qos,
                    deadline,
                    queue_wait.as_secs_f64(),
                    compute.as_secs_f64(),
                    0.0,
                    generation,
                );
                metrics.tracer().instant(
                    req.id,
                    EventKind::Terminal {
                        outcome: Outcome::Done,
                        qos: req.qos.map_or("none", |q| q.name()),
                        queue_us: queue_wait.as_micros() as u64,
                        compute_us: compute.as_micros() as u64,
                        stream_us: 0,
                        generation,
                        deadline,
                        tokens: req.tokens.len(),
                    },
                );
                let _ = req.reply.send(Response {
                    next_token: best as u32,
                    mean_nll: nll / (t - 1).max(1) as f64,
                    latency,
                    queue_wait,
                    generation,
                });
            }
        }
        Err(e) => {
            eprintln!("batch failed ({} request(s) dropped): {e:#}", requests.len());
            let tracer = engine.metrics_mut().tracer();
            for req in &requests {
                trace_terminal(tracer, req, Outcome::Failed);
            }
            return (0, requests.len());
        }
    }
    (suppressed, 0)
}

/// Final per-replica statistics, assembled from the engine at thread exit.
/// Distributions ship as [`Summary`](crate::util::stats::Summary) (merged
/// cluster-side without re-concatenating samples); the replica's span ring
/// is drained here, exactly once, into the report.
fn collect_report(
    spec: &ReplicaSpec,
    engine: &mut ServingEngine,
    executed_batches: usize,
    stolen_batches: usize,
) -> ReplicaReport {
    let (trace, trace_dropped) = engine.metrics_mut().take_trace();
    let m = engine.metrics();
    ReplicaReport {
        id: spec.id,
        requests: m.requests,
        tokens: m.tokens,
        executed_batches,
        stolen_batches,
        expert_calls: m.expert_calls,
        padded_rows: m.padded_tokens,
        useful_rows: m.useful_rows,
        waves: m.waves,
        max_concurrent_waves: m.max_concurrent_waves,
        wave_padded_rows: m.scheme_wave_stats().values().map(|s| s.padded_rows).sum(),
        wave_useful_rows: m.scheme_wave_stats().values().map(|s| s.useful_rows).sum(),
        max_queue_depth: m.max_queue_depth,
        swaps: m.swaps,
        replans: m.replans,
        last_drift: m.last_drift,
        drift_vector: m.drift_vector.clone(),
        replan_history: m.replan_history().to_vec(),
        shed_cancelled: m.shed_cancelled,
        qos_served: m.qos_served,
        slo: m.slo,
        served_by_generation: m.served_by_generation(),
        queue_wait_by_priority: m.queue_wait_by_priority_summary(),
        latency_by_class: m.latency_by_class_summary(),
        generation: engine.generation(),
        scheme_counts: engine.scheme_counts(),
        latency: m.latency_summary(),
        queue_wait: m.queue_wait_summary(),
        wave_latency: m.wave_latency_summary(),
        decode_steps: m.decode_steps,
        prefill_rows: m.prefill_rows,
        decode_rows: m.decode_rows,
        generated_tokens: m.generated_tokens,
        generations: m.generations,
        step_latency: m.step_latency_summary(),
        kv_peak_tokens: m.kv_peak_tokens,
        kv_budget_tokens: m.kv_budget_tokens,
        kv_used_tokens: m.kv_used_tokens,
        kv_shared_tokens: m.kv_shared_tokens,
        kv_avg_bits: m.kv_avg_bits,
        kv_preemptions: m.kv_preemptions,
        elapsed_s: m.elapsed(),
        trace,
        trace_dropped,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;
    use std::time::Duration;

    fn batch(n_tokens: usize) -> RoutedBatch {
        let (reply, _) = mpsc::channel();
        RoutedBatch { requests: vec![Request::new(vec![0u32; n_tokens], reply)] }
    }

    #[test]
    fn routed_batch_sheds_only_cancelled_requests() {
        use std::sync::atomic::Ordering;
        let (reply, _) = mpsc::channel();
        let keep = Request::new(vec![0u32; 3], reply.clone());
        let dead = Request::new(vec![0u32; 5], reply);
        dead.cancelled.store(true, Ordering::Release);
        let mut b = RoutedBatch { requests: vec![dead, keep] };
        assert_eq!(b.tokens(), 8);
        let shed = b.shed_cancelled();
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].tokens, 5, "shed info describes the dead request");
        assert_eq!(shed[0].qos, "none");
        assert_eq!(b.tokens(), 3, "live request survives the shed");
        assert!(b.shed_cancelled().is_empty(), "idempotent");
    }

    #[test]
    fn own_queue_has_priority_and_fifo_order() {
        let q = WorkQueues::new(2);
        q.push(0, batch(1));
        q.push(0, batch(2));
        q.push(1, batch(3));
        let (b, stolen) = q.pop(0).unwrap();
        assert!(!stolen);
        assert_eq!(b.tokens(), 1, "own front first");
        let (b, stolen) = q.pop(0).unwrap();
        assert!(!stolen);
        assert_eq!(b.tokens(), 2);
        // own queue empty, peer has work: steal it
        let (b, stolen) = q.pop(0).unwrap();
        assert!(stolen);
        assert_eq!(b.tokens(), 3);
    }

    #[test]
    fn steal_takes_oldest_from_deepest_peer() {
        let q = WorkQueues::new(3);
        q.push(1, batch(10));
        q.push(2, batch(20));
        q.push(2, batch(21));
        q.push(2, batch(22));
        let (b, stolen) = q.pop(0).unwrap();
        assert!(stolen);
        assert_eq!(b.tokens(), 20, "deepest peer's oldest batch is stolen first");
        assert_eq!(q.depths(), vec![0, 1, 2]);
    }

    #[test]
    fn loads_count_inflight_until_done() {
        let q = WorkQueues::new(2);
        q.push(0, batch(1));
        q.push(0, batch(2));
        assert_eq!(q.loads(), vec![2, 0]);
        let _ = q.pop(0).unwrap();
        assert_eq!(q.depths(), vec![1, 0], "popped batch left the deque");
        assert_eq!(q.loads(), vec![2, 0], "…but still counts as replica 0 load");
        q.done(0);
        assert_eq!(q.loads(), vec![1, 0]);
        // a steal moves the load to the thief
        let (_, stolen) = q.pop(1).unwrap();
        assert!(stolen);
        assert_eq!(q.loads(), vec![0, 1]);
        q.done(1);
        assert_eq!(q.loads(), vec![0, 0]);
    }

    #[test]
    fn capacity_wait_tracks_idle_inflight_and_dead() {
        let q = WorkQueues::new(2);
        assert!(q.wait_for_capacity(), "all idle at boot");
        q.push(0, batch(1));
        let _ = q.pop(0).unwrap(); // replica 0 busy (in flight)
        assert!(q.wait_for_capacity(), "replica 1 still idle");
        q.push(1, batch(2));
        let _ = q.pop(1).unwrap(); // both busy
        let q2 = q.clone();
        let t = thread::spawn(move || q2.wait_for_capacity());
        thread::sleep(Duration::from_millis(20));
        q.done(0); // a completion frees capacity and wakes the waiter
        assert!(t.join().unwrap());
        q.mark_dead(0);
        q.mark_dead(1);
        assert!(!q.wait_for_capacity(), "all replicas dead — no capacity ever");
    }

    #[test]
    fn try_pop_never_blocks_and_tracks_inflight() {
        let q = WorkQueues::new(2);
        assert!(matches!(q.try_pop(0), TryPop::Empty), "nothing queued");
        q.push(0, batch(3));
        match q.try_pop(0) {
            TryPop::Batch(b, stolen) => {
                assert_eq!(b.tokens(), 3);
                assert!(!stolen);
            }
            _ => panic!("own batch expected"),
        }
        assert_eq!(q.loads(), vec![1, 0], "in-flight until done");
        q.done(0);
        // steal path
        q.push(1, batch(5));
        match q.try_pop(0) {
            TryPop::Batch(b, stolen) => {
                assert_eq!(b.tokens(), 5);
                assert!(stolen);
            }
            _ => panic!("steal expected"),
        }
        q.done(0);
        q.close();
        assert!(matches!(q.try_pop(0), TryPop::Closed), "closed + drained");
    }

    #[test]
    fn pop_timeout_bounds_the_wait_and_still_delivers() {
        let q = WorkQueues::new(1);
        // nothing queued: gives up after the timeout instead of blocking
        let t0 = std::time::Instant::now();
        assert!(matches!(q.pop_timeout(0, Duration::from_millis(10)), TryPop::Empty));
        assert!(t0.elapsed() >= Duration::from_millis(10));
        // a concurrent push wakes the bounded wait like the blocking pop
        let q2 = q.clone();
        let t = thread::spawn(move || {
            matches!(q2.pop_timeout(0, Duration::from_secs(5)), TryPop::Batch(_, _))
        });
        thread::sleep(Duration::from_millis(20));
        q.push(0, batch(3));
        assert!(t.join().unwrap(), "push must wake the bounded wait");
        q.done(0);
        q.close();
        assert!(matches!(q.pop_timeout(0, Duration::from_millis(1)), TryPop::Closed));
    }

    #[test]
    fn decode_load_counts_toward_loads_but_not_capacity() {
        let q = WorkQueues::new(2);
        q.note_decode_load(0, 3);
        q.note_decode_load(1, 2);
        assert_eq!(q.loads(), vec![3, 2], "decode sequences are router load");
        assert!(
            q.wait_for_capacity(),
            "decoding replicas still count as capacity (they merge work per step)"
        );
        q.note_decode_load(0, 0);
        q.note_decode_load(1, 0);
        assert_eq!(q.loads(), vec![0, 0]);
    }

    #[test]
    fn close_drains_then_returns_none() {
        let q = WorkQueues::new(1);
        q.push(0, batch(7));
        q.close();
        assert!(q.pop(0).is_some(), "queued work survives close");
        assert!(q.pop(0).is_none(), "drained + closed pops None");
    }

    #[test]
    fn blocked_pop_wakes_on_push_and_on_close() {
        let q = WorkQueues::new(2);
        let q2 = q.clone();
        let t = thread::spawn(move || {
            let got = q2.pop(1); // blocks: nothing queued anywhere
            got.map(|(b, stolen)| (b.tokens(), stolen))
        });
        thread::sleep(Duration::from_millis(20));
        q.push(0, batch(9)); // routed to 0 — replica 1 must steal it
        assert_eq!(t.join().unwrap(), Some((9, true)));

        let q3 = q.clone();
        let t = thread::spawn(move || q3.pop(0).is_none());
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(t.join().unwrap(), "close wakes blocked pop with None");
    }

    #[test]
    fn kill_wakes_blocked_pop_and_revive_restores_service() {
        let q = WorkQueues::new(2);
        assert!(!q.kill_requested(0));
        // a blocked pop wakes with None on kill (not close)
        let q2 = q.clone();
        let t = thread::spawn(move || q2.pop(0).is_none());
        thread::sleep(Duration::from_millis(20));
        q.request_kill(0);
        assert!(t.join().unwrap(), "kill wakes the blocked pop with None");
        assert!(q.kill_requested(0));
        // killed replicas get no work, even with batches queued for them…
        q.push(0, batch(4));
        assert!(matches!(q.try_pop(0), TryPop::Closed));
        assert!(matches!(q.pop_timeout(0, Duration::from_millis(1)), TryPop::Closed));
        // …but the survivors can still steal the backlog
        let (b, stolen) = q.pop(1).unwrap();
        assert!(stolen);
        assert_eq!(b.tokens(), 4);
        q.done(1);
        // dead + killed: no capacity once the peer dies too
        q.mark_dead(0);
        q.mark_dead(1);
        assert!(!q.wait_for_capacity());
        // revive clears both flags and restores the replica as capacity
        q.revive(0);
        assert!(!q.kill_requested(0));
        assert!(q.wait_for_capacity(), "revived replica counts as capacity again");
        q.push(0, batch(6));
        let (b, stolen) = q.pop(0).unwrap();
        assert!(!stolen);
        assert_eq!(b.tokens(), 6, "revived replica serves its own queue");
        q.done(0);
    }

    #[test]
    fn boot_status_mirrors_the_allocation() {
        use crate::quant::QuantScheme;
        let cfg = ModelConfig {
            name: "boot".into(),
            vocab: 32,
            hidden: 16,
            layers: 2,
            heads: 2,
            n_experts: 4,
            n_shared: 1,
            topk: 2,
            inter: 8,
            dense_first: false,
            seq_len: 12,
        };
        let alloc = Allocation::uniform(&cfg, QuantScheme::W8A8);
        let s = ReplicaStatus::boot(&cfg, &alloc);
        assert_eq!(s.generation, 0);
        assert_eq!(s.schemes.len(), 2);
        for layer in &s.schemes {
            assert_eq!(layer.len(), 5, "4 routed + 1 shared");
            assert!(layer.iter().all(|&f| f == RuntimeScheme::W8A8));
        }
        for f in &s.live_freqs {
            assert_eq!(f.len(), 4, "frequencies track routed experts only");
            let sum: f64 = f.iter().sum();
            assert!((sum - 1.0).abs() < 1e-12);
        }
    }
}
