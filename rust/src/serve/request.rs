//! Typed serving requests, cancellable tickets, and bounded admission
//! (DESIGN.md §Serving-API).
//!
//! The cluster front door used to be `submit(Vec<u32>) → Receiver`:
//! untyped, unbounded, uncancellable. This module is the request model the
//! QoS-aware redesign replaced it with:
//!
//! * [`ServeRequest`] — a builder carrying the token sequence plus the
//!   knobs the downstream machinery can actually steer on: [`Priority`]
//!   (orders batch cutting, with aging so low priority never starves),
//!   a per-request deadline/TTL (feeds the batcher's deadline-aware cut
//!   and the admission controller's projected-miss shedding), and an
//!   optional [`QosClass`] hinting the accuracy/perf exponent `r` the
//!   online replanner solves with.
//! * [`Ticket`] — the handle submission returns: non-blocking
//!   [`poll`](Ticket::poll), blocking [`wait`](Ticket::wait), and
//!   [`cancel`](Ticket::cancel). Cancelled work is shed at the next batch
//!   cut (router) or queue pop (replica) instead of executing, and a
//!   cancelled ticket never yields a [`Response`] even if the reply racing
//!   the cancel was already in flight.
//! * [`AdmissionState`] — the bounded admission layer. `try_submit`
//!   returns [`Admission::Rejected`] with a reason and a `retry_after`
//!   estimate under load shedding (queue-depth bound, projected
//!   deadline-miss); blocking `submit` waits for room up to a budget.
//!
//! Everything here is plain data + sync primitives: no engine, no PJRT —
//! unit-testable without artifacts.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::obs::{EventKind, SpanCollector, Track, TraceClock, TraceConfig, TraceEvent};

use super::queue::Response;

/// Request priority: orders batch cutting in the continuous batcher.
/// Higher priorities cut first; aging (see
/// [`BatchPolicy::aging`](super::queue::BatchPolicy)) lifts waiting
/// requests one level per quantum so low priority is delayed, never
/// starved.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    Low = 0,
    Normal = 1,
    High = 2,
}

impl Priority {
    pub const ALL: [Priority; 3] = [Priority::Low, Priority::Normal, Priority::High];

    /// Dense index for per-priority accounting arrays.
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

/// Quality-of-service class: a hint for the accuracy/perf exponent `r`
/// the online replanner re-solves with (the QoS-tuning direction — Imani
/// et al.). Interactive traffic leans the plan toward throughput (lower
/// `r`), batch/offline traffic toward accuracy (higher `r`); `Standard`
/// keeps the configured exponent. Replicas count served requests per
/// class and blend the hints traffic-weighted at replan time.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QosClass {
    /// Latency-sensitive: favor throughput (`r` pulled toward 0.5).
    Interactive = 0,
    /// No preference: the allocator's configured `r`.
    Standard = 1,
    /// Offline/quality-sensitive: favor accuracy (`r` pulled toward 0.95).
    Batch = 2,
}

impl QosClass {
    pub const ALL: [QosClass; 3] = [QosClass::Interactive, QosClass::Standard, QosClass::Batch];

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            QosClass::Interactive => "interactive",
            QosClass::Standard => "standard",
            QosClass::Batch => "batch",
        }
    }

    /// Absolute `r` this class pulls the replanner toward; `None` keeps
    /// the configured exponent.
    pub fn r_hint(self) -> Option<f64> {
        match self {
            QosClass::Interactive => Some(0.5),
            QosClass::Standard => None,
            QosClass::Batch => Some(0.95),
        }
    }
}

/// What the request asks the cluster to do with its tokens.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeKind {
    /// Whole-sequence scoring: one forward, one [`Response`].
    Score,
    /// KV-cached generation (DESIGN.md §Decode-Loop): prefill the prompt,
    /// then greedy-decode up to `max_new_tokens` new tokens, streaming
    /// each one through the ticket as it lands. Decoding stops early when
    /// a `stop` token is generated (the stop token itself is streamed).
    Generate { max_new_tokens: usize, stop: Vec<u32> },
}

/// Why a generation stopped (terminal [`StreamEvent::Done`] payload).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// A stop token was generated.
    Stop,
    /// `max_new_tokens` tokens were generated.
    Length,
    /// The ticket was cancelled between decode steps.
    Cancelled,
    /// The engine's step forward failed (see the replica log).
    Failed,
}

/// One event on a generation ticket's token stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamEvent {
    /// A generated token and its index in the generated suffix (0-based).
    Token { token: u32, index: usize },
    /// Terminal event: the generation finished with `generated` tokens.
    Done { reason: FinishReason, generated: usize },
}

/// A typed serving request: tokens plus QoS knobs, built fluently.
///
/// ```ignore
/// let req = ServeRequest::new(tokens)
///     .priority(Priority::High)
///     .deadline(Duration::from_millis(250))
///     .qos(QosClass::Interactive);
/// let ticket = cluster.submit_request(req)?;
///
/// // KV-cached generation with token streaming:
/// let ticket = cluster.submit_request(ServeRequest::generate(prompt, 32, vec![eos]))?;
/// while let Ok(StreamEvent::Token { token, .. }) = ticket.wait_event(timeout) { … }
/// ```
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub tokens: Vec<u32>,
    pub priority: Priority,
    /// Response deadline as a TTL from admission. Feeds the batcher's
    /// deadline-aware cut and the admission controller's projected-miss
    /// shedding; `None` means no deadline.
    pub ttl: Option<Duration>,
    pub qos: Option<QosClass>,
    pub kind: ServeKind,
}

impl ServeRequest {
    pub fn new(tokens: Vec<u32>) -> ServeRequest {
        ServeRequest {
            tokens,
            priority: Priority::Normal,
            ttl: None,
            qos: None,
            kind: ServeKind::Score,
        }
    }

    /// A generation request: prefill `prompt`, then decode up to
    /// `max_new_tokens` greedy tokens, stopping early on any of `stop`.
    /// The returned ticket streams tokens as they land
    /// ([`Ticket::wait_event`]) and still yields a final [`Response`]
    /// ([`Ticket::wait`]) so admission accounting is uniform across kinds.
    pub fn generate(prompt: Vec<u32>, max_new_tokens: usize, stop: Vec<u32>) -> ServeRequest {
        ServeRequest {
            kind: ServeKind::Generate { max_new_tokens, stop },
            ..ServeRequest::new(prompt)
        }
    }

    pub fn priority(mut self, p: Priority) -> ServeRequest {
        self.priority = p;
        self
    }

    /// Per-request deadline, as a TTL measured from admission.
    pub fn deadline(mut self, ttl: Duration) -> ServeRequest {
        self.ttl = Some(ttl);
        self
    }

    pub fn qos(mut self, q: QosClass) -> ServeRequest {
        self.qos = Some(q);
        self
    }

    /// True for the traffic classes the admission quota protects: `High`
    /// priority or `Interactive` QoS (see
    /// [`AdmissionConfig::privileged_reserve`]).
    pub fn is_privileged(&self) -> bool {
        self.priority == Priority::High || self.qos == Some(QosClass::Interactive)
    }
}

/// Why admission turned a request away.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The admission queue is at its sequence or token bound.
    QueueFull,
    /// The projected queue wait already exceeds the request's deadline —
    /// executing it would only burn capacity on a guaranteed miss.
    DeadlineUnmeetable,
    /// The unreserved share of the queue is exhausted: remaining slots are
    /// held back for `High`/`Interactive` traffic
    /// ([`AdmissionConfig::privileged_reserve`]), so this unprivileged
    /// request is shed even though the queue is not yet at its full bound.
    ClassQuota,
    /// Every replica's KV page pool is too full for the generation's
    /// prompt while decode backlogs exist: queueing it would only deepen
    /// the decode pending FIFO, so it is shed with a `retry_after` derived
    /// from the observed page-release rate.
    KvExhausted,
}

impl RejectReason {
    /// Stable name for trace events and logs.
    pub fn name(self) -> &'static str {
        match self {
            RejectReason::QueueFull => "queue-full",
            RejectReason::DeadlineUnmeetable => "deadline-unmeetable",
            RejectReason::ClassQuota => "class-quota",
            RejectReason::KvExhausted => "kv-exhausted",
        }
    }
}

/// Outcome of a non-blocking submission.
pub enum Admission {
    Admitted(Ticket),
    Rejected {
        /// Admission-assigned request id — rejections get ids too, so
        /// load-shedding is attributable per request in the trace.
        id: u64,
        reason: RejectReason,
        /// Estimate of when retrying is worthwhile (queue-drain
        /// projection; a floor of 1 ms even when the rate is unknown).
        retry_after: Duration,
    },
}

impl Admission {
    pub fn ticket(self) -> Option<Ticket> {
        match self {
            Admission::Admitted(t) => Some(t),
            Admission::Rejected { .. } => None,
        }
    }

    pub fn is_admitted(&self) -> bool {
        matches!(self, Admission::Admitted(_))
    }
}

/// Handle to an admitted request. Dropping the ticket abandons the reply
/// (the response, if any, goes to a dead channel); [`cancel`](Self::cancel)
/// additionally sheds the queued work before it executes.
pub struct Ticket {
    pub(crate) rx: mpsc::Receiver<Response>,
    pub(crate) cancel: Arc<AtomicBool>,
    pub(crate) id: u64,
    /// Token stream of a generation request (`None` for scoring). Events
    /// arrive one per decode step; the terminal event is
    /// [`StreamEvent::Done`].
    pub(crate) stream: Option<mpsc::Receiver<StreamEvent>>,
}

impl Ticket {
    /// Admission-assigned request id (unique per cluster).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// True when this ticket carries a generation token stream.
    pub fn is_generation(&self) -> bool {
        self.stream.is_some()
    }

    /// Non-blocking stream poll: the next [`StreamEvent`] if one has
    /// landed. Always `None` for scoring tickets — and always `None` after
    /// [`cancel`](Self::cancel): a cancelled ticket never yields events,
    /// even ones that raced the cancellation into the channel.
    pub fn try_next_event(&self) -> Option<StreamEvent> {
        if self.is_cancelled() {
            return None;
        }
        self.stream.as_ref()?.try_recv().ok()
    }

    /// Block up to `timeout` for the next stream event. Errors for scoring
    /// tickets, after cancellation, or once the serving side closed the
    /// stream (the terminal [`StreamEvent::Done`] has already been read).
    pub fn wait_event(&self, timeout: Duration) -> anyhow::Result<StreamEvent> {
        if self.is_cancelled() {
            anyhow::bail!("ticket {} cancelled", self.id);
        }
        let stream = self
            .stream
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("ticket {} is not a generation", self.id))?;
        stream
            .recv_timeout(timeout)
            .map_err(|e| anyhow::anyhow!("ticket {} stream: {e}", self.id))
    }

    /// Drain the stream until [`StreamEvent::Done`] (or `timeout` per
    /// event), returning the generated tokens and the finish reason. Call
    /// from a fresh ticket: the terminal event's `generated` count is
    /// cross-checked against the tokens read *by this call*, so events
    /// consumed earlier via [`wait_event`](Self::wait_event) would trip
    /// the accounting check.
    pub fn collect_tokens(&self, timeout: Duration) -> anyhow::Result<(Vec<u32>, FinishReason)> {
        let mut tokens = Vec::new();
        loop {
            match self.wait_event(timeout)? {
                StreamEvent::Token { token, .. } => tokens.push(token),
                StreamEvent::Done { reason, generated } => {
                    anyhow::ensure!(
                        generated == tokens.len(),
                        "stream accounting: Done says {generated}, saw {}",
                        tokens.len()
                    );
                    return Ok((tokens, reason));
                }
            }
        }
    }

    /// Non-blocking poll. `None` while pending — and always `None` after
    /// [`cancel`](Self::cancel): a cancelled ticket never yields a
    /// response, even if one raced the cancellation into the channel.
    pub fn poll(&self) -> Option<Response> {
        if self.is_cancelled() {
            return None;
        }
        self.rx.try_recv().ok()
    }

    /// Block until the response arrives. Errors if the ticket was
    /// cancelled or the serving side dropped the request (shutdown).
    pub fn wait(&self) -> anyhow::Result<Response> {
        if self.is_cancelled() {
            anyhow::bail!("ticket {} cancelled", self.id);
        }
        self.rx.recv().map_err(|_| {
            anyhow::anyhow!("request {} dropped (cancelled or cluster closed)", self.id)
        })
    }

    /// Block up to `timeout` for the response.
    pub fn wait_timeout(&self, timeout: Duration) -> anyhow::Result<Response> {
        if self.is_cancelled() {
            anyhow::bail!("ticket {} cancelled", self.id);
        }
        self.rx
            .recv_timeout(timeout)
            .map_err(|e| anyhow::anyhow!("request {}: {e}", self.id))
    }

    /// Request cancellation (idempotent). Queued work is dropped at the
    /// next batch cut or replica pop; work already executing completes but
    /// its response is suppressed — this ticket will never yield one.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.load(Ordering::Acquire)
    }

    /// The raw reply receiver — the legacy `submit` shim's return value.
    /// Forfeits cancellation and the post-cancel response guard.
    pub fn into_receiver(self) -> mpsc::Receiver<Response> {
        self.rx
    }
}

/// Bounded-admission policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// Max sequences admitted but not yet cut into a routed batch.
    pub max_queued_seqs: usize,
    /// Max concatenated tokens admitted but not yet cut.
    pub max_queued_tokens: usize,
    /// How long a blocking `submit` may wait for queue room before giving
    /// up (the legacy shim uses this; the defaults make blocking rare).
    pub submit_budget: Duration,
    /// Reject requests whose deadline the projected queue wait already
    /// blows (needs a service-rate estimate; admits until warmed up).
    pub shed_on_projected_miss: bool,
    /// Fraction of `max_queued_seqs` reserved for privileged traffic
    /// (`High` priority or `Interactive` QoS): unprivileged requests are
    /// bounded at `max_queued_seqs - ceil(reserve)` slots, so a `Low`
    /// flood can fill at most the unreserved share and interactive
    /// arrivals always find queue room. `0.0` (the default) disables the
    /// quota — admission fairness is an explicit policy choice, and at
    /// least one unreserved slot always remains so unprivileged traffic is
    /// delayed, never locked out.
    pub privileged_reserve: f64,
    /// Derive the privileged reserve from the live QoS mix instead of the
    /// static knob: admission keeps an EWMA of the privileged share of
    /// arrivals and reserves that fraction (capped at
    /// [`MAX_AUTO_RESERVE`]), so the front door self-tunes — a mostly
    /// interactive mix holds back more slots, a batch-only mix holds back
    /// none. `privileged_reserve` seeds the EWMA as the prior.
    pub auto_reserve: bool,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            // generous: the bound exists to cap pathological backlogs, not
            // to shape steady-state traffic
            max_queued_seqs: 4096,
            max_queued_tokens: 1 << 22,
            submit_budget: Duration::from_secs(30),
            shed_on_projected_miss: true,
            privileged_reserve: 0.0,
            auto_reserve: false,
        }
    }
}

impl AdmissionConfig {
    /// Sequence bound for unprivileged traffic: the full bound minus the
    /// privileged reservation, floored at one slot.
    pub fn unprivileged_seq_bound(&self) -> usize {
        self.unprivileged_seq_bound_for(self.privileged_reserve)
    }

    /// [`unprivileged_seq_bound`](Self::unprivileged_seq_bound) for an
    /// explicit reserve fraction (the auto-reserve path passes the live
    /// privileged-share EWMA here).
    pub fn unprivileged_seq_bound_for(&self, reserve: f64) -> usize {
        let reserve = (self.max_queued_seqs as f64 * reserve.clamp(0.0, 1.0)).ceil() as usize;
        self.max_queued_seqs.saturating_sub(reserve).max(1)
    }
}

/// One request of a burst admission
/// ([`AdmissionState::try_admit_burst`]): the per-request inputs of
/// [`AdmissionState::try_admit_for`], batched so a whole arrival burst is
/// decided under one lock acquisition.
#[derive(Clone, Copy, Debug)]
pub struct AdmitArgs {
    pub tokens: usize,
    pub ttl: Option<Duration>,
    pub privileged: bool,
    /// QoS name for the trace (see [`QosClass::name`]; the cluster passes
    /// "none" when unset).
    pub qos: &'static str,
    /// Priority name for the trace (see [`Priority::name`]).
    pub priority: &'static str,
}

/// Admission counters reported at shutdown ([`crate::coordinator::metrics::ClusterReport`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AdmissionReport {
    /// Requests admitted (ticket issued).
    pub admitted: usize,
    pub rejected_queue_full: usize,
    pub rejected_deadline: usize,
    /// Unprivileged requests shed by the class quota while reserved slots
    /// remained (admission fairness).
    pub rejected_quota: usize,
    /// Generations shed because every replica's KV page pool was full
    /// while decode backlogs existed (KV backpressure — `retry_after`
    /// comes from the observed page-release rate).
    pub rejected_kv: usize,
    /// Admitted requests that never produced a response because they were
    /// cancelled: shed at a batch cut, shed at a replica pop, or
    /// suppressed at reply time after a late cancel.
    pub cancelled: usize,
    /// Admitted requests that never produced a response because their
    /// batch's forward pass failed (engine error — see the replica log).
    pub failed: usize,
}

impl AdmissionReport {
    pub fn rejected(&self) -> usize {
        self.rejected_queue_full + self.rejected_deadline + self.rejected_quota + self.rejected_kv
    }

    /// Every admitted request is accounted for exactly once at a drained
    /// shutdown: `admitted == responses + cancelled + failed`, where
    /// `responses` is the cluster's served-request total.
    pub fn unserved(&self) -> usize {
        self.cancelled + self.failed
    }
}

struct AdmissionInner {
    queued_seqs: usize,
    queued_tokens: usize,
    /// EWMA of one replica's executed tokens/second (0 = unknown).
    /// Replicas fold their per-batch samples into a single estimate; the
    /// cluster drain rate is this times the replica count.
    service_rate_tps: f64,
    /// EWMA of the privileged share of arrivals (negative = no sample
    /// yet). Drives the class-quota bound when
    /// [`AdmissionConfig::auto_reserve`] is on; updated on every admission
    /// decision, so it is a pure function of the arrival sequence
    /// (deterministic under burst-atomic submission).
    privileged_share: f64,
    report: AdmissionReport,
    next_id: u64,
    /// Admission-track span collector — admit/reject events ride the
    /// admission mutex the front door already takes (no new lock).
    tracer: SpanCollector,
}

/// Shared bounded-admission state: queue-depth accounting on the submit
/// side, drain/service notes from the router and replicas, and the
/// load-shedding decision itself. One mutex guards everything — admission
/// is O(1) bookkeeping, never on the execute path's critical section.
pub struct AdmissionState {
    inner: Mutex<AdmissionInner>,
    /// Signalled whenever queued work drains (cut or shed) — what blocking
    /// submits wait on.
    freed: Condvar,
    /// Engine replicas draining the queue in parallel: scales the
    /// per-replica service-rate EWMA up to a cluster drain rate for the
    /// wait projections. Optimistic when replicas die mid-run (shedding
    /// turns conservative, never over-eager).
    replicas: usize,
}

/// Service-rate EWMA step for [`AdmissionState::note_service`].
const RATE_ALPHA: f64 = 0.3;
/// Privileged-share EWMA step (per admission decision) for
/// [`AdmissionConfig::auto_reserve`].
const SHARE_ALPHA: f64 = 0.05;
/// Auto-reserve cap: even an all-privileged mix leaves this much of the
/// queue open to unprivileged traffic (delay, never lock out — the same
/// contract as the static knob's one-slot floor, but proportional).
pub const MAX_AUTO_RESERVE: f64 = 0.9;
/// `retry_after` clamp.
const RETRY_MIN: Duration = Duration::from_millis(1);
const RETRY_MAX: Duration = Duration::from_secs(5);
/// `retry_after` fallback before any service-rate sample exists.
const RETRY_DEFAULT: Duration = Duration::from_millis(50);

fn clamp_retry(d: Duration) -> Duration {
    d.clamp(RETRY_MIN, RETRY_MAX)
}

impl AdmissionState {
    pub fn new(replicas: usize) -> Arc<AdmissionState> {
        Arc::new(AdmissionState {
            inner: Mutex::new(AdmissionInner {
                queued_seqs: 0,
                queued_tokens: 0,
                service_rate_tps: 0.0,
                privileged_share: -1.0,
                report: AdmissionReport::default(),
                next_id: 1,
                tracer: SpanCollector::disabled(Track::Admission),
            }),
            freed: Condvar::new(),
            replicas: replicas.max(1),
        })
    }

    /// Projected cluster drain rate, tokens/second (0 until warmed up).
    fn drain_rate(&self, g: &AdmissionInner) -> f64 {
        g.service_rate_tps * self.replicas as f64
    }

    /// Non-blocking admission decision for a `tokens`-token request with
    /// an optional deadline TTL. `privileged` requests (`High` priority or
    /// `Interactive` QoS — see [`ServeRequest::is_privileged`]) may use the
    /// reserved share of the queue; the rest are bounded at
    /// [`AdmissionConfig::unprivileged_seq_bound`]. On success the request
    /// counts as queued until
    /// [`note_cut`](Self::note_cut)/[`note_shed_at_cut`](Self::note_shed_at_cut)
    /// releases it; the returned id is the ticket id.
    pub fn try_admit(
        &self,
        cfg: &AdmissionConfig,
        tokens: usize,
        ttl: Option<Duration>,
        privileged: bool,
    ) -> Result<u64, (RejectReason, Duration)> {
        self.try_admit_for(cfg, tokens, ttl, privileged, "standard", "normal")
            .map_err(|(reason, retry, _)| (reason, retry))
    }

    /// [`try_admit`](Self::try_admit) with the request's QoS/priority names
    /// for the trace — rejections carry the id they were assigned.
    pub fn try_admit_for(
        &self,
        cfg: &AdmissionConfig,
        tokens: usize,
        ttl: Option<Duration>,
        privileged: bool,
        qos: &'static str,
        priority: &'static str,
    ) -> Result<u64, (RejectReason, Duration, u64)> {
        let mut g = self.inner.lock().unwrap();
        self.admit_locked(&mut g, cfg, tokens, ttl, privileged, qos, priority)
    }

    /// Admit a whole burst under ONE lock acquisition: decisions are made
    /// in item order against queue state no concurrent drain or submit can
    /// interleave with, so the outcome vector is a pure function of the
    /// queue state at entry plus the items — the determinism anchor the
    /// scenario replay driver leans on. Each item gets the same decision
    /// `try_admit_for` would have made.
    pub fn try_admit_burst(
        &self,
        cfg: &AdmissionConfig,
        items: &[AdmitArgs],
    ) -> Vec<Result<u64, (RejectReason, Duration, u64)>> {
        let mut g = self.inner.lock().unwrap();
        items
            .iter()
            .map(|a| {
                self.admit_locked(&mut g, cfg, a.tokens, a.ttl, a.privileged, a.qos, a.priority)
            })
            .collect()
    }

    /// Blocking admission: wait up to `cfg.submit_budget` for queue room.
    /// Projected-deadline rejection still applies — waiting only makes a
    /// doomed deadline worse. A quota rejection waits like queue-full:
    /// drain frees unreserved slots too.
    pub fn admit_blocking(
        &self,
        cfg: &AdmissionConfig,
        tokens: usize,
        ttl: Option<Duration>,
        privileged: bool,
    ) -> Result<u64, (RejectReason, Duration)> {
        self.admit_blocking_for(cfg, tokens, ttl, privileged, "standard", "normal")
            .map_err(|(reason, retry, _)| (reason, retry))
    }

    /// [`admit_blocking`](Self::admit_blocking) with the request's
    /// QoS/priority names for the trace.
    pub fn admit_blocking_for(
        &self,
        cfg: &AdmissionConfig,
        tokens: usize,
        ttl: Option<Duration>,
        privileged: bool,
        qos: &'static str,
        priority: &'static str,
    ) -> Result<u64, (RejectReason, Duration, u64)> {
        let deadline = Instant::now() + cfg.submit_budget;
        let mut g = self.inner.lock().unwrap();
        loop {
            match self.admit_locked(&mut g, cfg, tokens, ttl, privileged, qos, priority) {
                Ok(id) => return Ok(id),
                Err((RejectReason::DeadlineUnmeetable, r, id)) => {
                    return Err((RejectReason::DeadlineUnmeetable, r, id))
                }
                Err(full) => {
                    let left = deadline.saturating_duration_since(Instant::now());
                    if left.is_zero() {
                        return Err(full);
                    }
                    let (guard, _timeout) = self.freed.wait_timeout(g, left).unwrap();
                    g = guard;
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn admit_locked(
        &self,
        g: &mut AdmissionInner,
        cfg: &AdmissionConfig,
        tokens: usize,
        ttl: Option<Duration>,
        privileged: bool,
        qos: &'static str,
        priority: &'static str,
    ) -> Result<u64, (RejectReason, Duration, u64)> {
        let drain = self.drain_rate(g);
        // crude drain projection: half the backlog at the cluster rate
        let backlog_retry = if drain > 0.0 {
            clamp_retry(Duration::from_secs_f64(g.queued_tokens as f64 / drain / 2.0))
        } else {
            RETRY_DEFAULT
        };
        let reject = |g: &mut AdmissionInner, reason: RejectReason, retry: Duration| {
            // rejections are assigned ids too, so load-shedding is
            // per-request attributable in the trace (instant, no span)
            let id = g.next_id;
            g.next_id += 1;
            g.tracer.instant(id, EventKind::Rejected { reason: reason.name() });
            (reason, retry, id)
        };
        // fold this arrival into the privileged-share EWMA before the
        // quota decision, so an auto reserve tracks the mix including the
        // request being decided (pure function of the arrival sequence)
        let sample = if privileged { 1.0 } else { 0.0 };
        g.privileged_share = if g.privileged_share < 0.0 {
            // first arrival: seed from the static knob as the prior
            (1.0 - SHARE_ALPHA) * cfg.privileged_reserve.clamp(0.0, 1.0) + SHARE_ALPHA * sample
        } else {
            (1.0 - SHARE_ALPHA) * g.privileged_share + SHARE_ALPHA * sample
        };
        if g.queued_seqs + 1 > cfg.max_queued_seqs || g.queued_tokens + tokens > cfg.max_queued_tokens
        {
            g.report.rejected_queue_full += 1;
            return Err(reject(g, RejectReason::QueueFull, backlog_retry));
        }
        let unprivileged_bound = if cfg.auto_reserve {
            cfg.unprivileged_seq_bound_for(g.privileged_share.min(MAX_AUTO_RESERVE))
        } else {
            cfg.unprivileged_seq_bound()
        };
        if !privileged && g.queued_seqs + 1 > unprivileged_bound {
            // inside the full bound but past the unreserved share: the
            // remaining slots are held for High/Interactive arrivals
            g.report.rejected_quota += 1;
            return Err(reject(g, RejectReason::ClassQuota, backlog_retry));
        }
        if cfg.shed_on_projected_miss {
            if let (Some(ttl), true) = (ttl, drain > 0.0) {
                let projected =
                    Duration::from_secs_f64((g.queued_tokens + tokens) as f64 / drain);
                if projected > ttl {
                    g.report.rejected_deadline += 1;
                    return Err(reject(
                        g,
                        RejectReason::DeadlineUnmeetable,
                        clamp_retry(projected - ttl),
                    ));
                }
            }
        }
        g.queued_seqs += 1;
        g.queued_tokens += tokens;
        g.report.admitted += 1;
        let id = g.next_id;
        g.next_id += 1;
        g.tracer.instant(id, EventKind::Admitted { qos, priority, tokens });
        Ok(id)
    }

    /// Roll back an admission whose channel send failed (router gone). The
    /// trace keeps its admit event and closes it with a failed terminal so
    /// begin/end pairs stay matched.
    pub fn abort_admit(&self, id: u64, tokens: usize) {
        let mut g = self.inner.lock().unwrap();
        g.queued_seqs = g.queued_seqs.saturating_sub(1);
        g.queued_tokens = g.queued_tokens.saturating_sub(tokens);
        g.report.admitted = g.report.admitted.saturating_sub(1);
        g.tracer.instant(
            id,
            EventKind::Terminal {
                outcome: crate::obs::Outcome::Failed,
                qos: "standard",
                queue_us: 0,
                compute_us: 0,
                stream_us: 0,
                generation: 0,
                deadline: crate::obs::Deadline::None,
                tokens,
            },
        );
        drop(g);
        self.freed.notify_all();
    }

    /// Record a KV-backpressure rejection decided by the cluster front
    /// door (the page-pool check lives outside the admission queue
    /// bookkeeping): assigns the request an id, traces the rejection, and
    /// returns the triple `try_submit` turns into `Admission::Rejected`.
    /// `retry_after` should come from the shortfall over the observed
    /// page-release rate; it is clamped like every other retry hint.
    pub fn reject_kv(&self, retry: Duration) -> (RejectReason, Duration, u64) {
        let mut g = self.inner.lock().unwrap();
        g.report.rejected_kv += 1;
        let id = g.next_id;
        g.next_id += 1;
        g.tracer
            .instant(id, EventKind::Rejected { reason: RejectReason::KvExhausted.name() });
        (RejectReason::KvExhausted, clamp_retry(retry), id)
    }

    /// Swap in a live admission-track collector (called once at cluster
    /// boot, before any submission).
    pub fn enable_trace(&self, clock: TraceClock, cfg: TraceConfig) {
        let mut g = self.inner.lock().unwrap();
        g.tracer = SpanCollector::new(clock, Track::Admission, cfg);
    }

    /// Drain the admission-track events (cluster shutdown).
    pub fn take_trace(&self) -> (Vec<TraceEvent>, usize) {
        self.inner.lock().unwrap().tracer.drain()
    }

    /// `seqs` requests totalling `tokens` left the admission queue in a
    /// routed batch (router side, at the cut).
    pub fn note_cut(&self, seqs: usize, tokens: usize) {
        if seqs == 0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.queued_seqs = g.queued_seqs.saturating_sub(seqs);
        g.queued_tokens = g.queued_tokens.saturating_sub(tokens);
        drop(g);
        self.freed.notify_all();
    }

    /// `seqs` cancelled requests were shed from the admission queue at a
    /// cut: releases their queue slots and counts them cancelled.
    pub fn note_shed_at_cut(&self, seqs: usize, tokens: usize) {
        if seqs == 0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.queued_seqs = g.queued_seqs.saturating_sub(seqs);
        g.queued_tokens = g.queued_tokens.saturating_sub(tokens);
        g.report.cancelled += seqs;
        drop(g);
        self.freed.notify_all();
    }

    /// `n` requests already cut into batches were cancelled before (or
    /// suppressed at) reply — replica side; their queue slots were
    /// released at the cut.
    pub fn note_cancelled(&self, n: usize) {
        if n == 0 {
            return;
        }
        self.inner.lock().unwrap().report.cancelled += n;
    }

    /// `n` requests got no reply because their batch's forward pass
    /// failed — keeps the admitted/served reconciliation honest under
    /// engine errors instead of silently leaking requests.
    pub fn note_failed(&self, n: usize) {
        if n == 0 {
            return;
        }
        self.inner.lock().unwrap().report.failed += n;
    }

    /// Fold one executed batch into the service-rate estimate. Samples
    /// come from individual replicas, so the EWMA tracks a *per-replica*
    /// rate; projections multiply by the replica count.
    pub fn note_service(&self, tokens: usize, elapsed: Duration) {
        let secs = elapsed.as_secs_f64();
        if tokens == 0 || secs <= 0.0 {
            return;
        }
        let rate = tokens as f64 / secs;
        let mut g = self.inner.lock().unwrap();
        g.service_rate_tps = if g.service_rate_tps == 0.0 {
            rate
        } else {
            (1.0 - RATE_ALPHA) * g.service_rate_tps + RATE_ALPHA * rate
        };
    }

    /// Current queued (admitted, not yet cut) sequences and tokens.
    pub fn queued(&self) -> (usize, usize) {
        let g = self.inner.lock().unwrap();
        (g.queued_seqs, g.queued_tokens)
    }

    /// Smoothed per-replica executed-tokens/second estimate (0 until
    /// warmed up). Multiply by the replica count for the cluster drain
    /// rate the projections use.
    pub fn service_rate_tps(&self) -> f64 {
        self.inner.lock().unwrap().service_rate_tps
    }

    /// Smoothed privileged share of arrivals (`None` before any admission
    /// decision). This is the fraction [`AdmissionConfig::auto_reserve`]
    /// holds back for `High`/`Interactive` traffic, capped at
    /// [`MAX_AUTO_RESERVE`].
    pub fn privileged_share(&self) -> Option<f64> {
        let g = self.inner.lock().unwrap();
        (g.privileged_share >= 0.0).then_some(g.privileged_share)
    }

    pub fn report(&self) -> AdmissionReport {
        self.inner.lock().unwrap().report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn cfg(seqs: usize, tokens: usize) -> AdmissionConfig {
        AdmissionConfig {
            max_queued_seqs: seqs,
            max_queued_tokens: tokens,
            submit_budget: Duration::from_millis(50),
            shed_on_projected_miss: true,
            privileged_reserve: 0.0,
            auto_reserve: false,
        }
    }

    fn args(tokens: usize, privileged: bool) -> AdmitArgs {
        AdmitArgs { tokens, ttl: None, privileged, qos: "standard", priority: "normal" }
    }

    #[test]
    fn builder_defaults_and_fluent_knobs() {
        let r = ServeRequest::new(vec![1, 2, 3]);
        assert_eq!(r.priority, Priority::Normal);
        assert!(r.ttl.is_none() && r.qos.is_none());
        assert_eq!(r.kind, ServeKind::Score);
        assert!(!r.is_privileged());
        let r = r
            .priority(Priority::High)
            .deadline(Duration::from_millis(100))
            .qos(QosClass::Interactive);
        assert_eq!(r.priority, Priority::High);
        assert_eq!(r.ttl, Some(Duration::from_millis(100)));
        assert_eq!(r.qos, Some(QosClass::Interactive));
        assert!(r.is_privileged());
    }

    #[test]
    fn generate_builder_carries_decode_knobs() {
        let r = ServeRequest::generate(vec![5, 6], 12, vec![0]);
        assert_eq!(r.tokens, vec![5, 6]);
        assert_eq!(r.kind, ServeKind::Generate { max_new_tokens: 12, stop: vec![0] });
        assert_eq!(r.priority, Priority::Normal, "QoS knobs still default");
        let r = r.priority(Priority::High).qos(QosClass::Interactive);
        assert!(r.is_privileged());
        assert!(matches!(r.kind, ServeKind::Generate { .. }), "knobs preserve the kind");
        // Interactive QoS alone is privileged too
        assert!(ServeRequest::new(vec![1]).qos(QosClass::Interactive).is_privileged());
        assert!(!ServeRequest::new(vec![1]).priority(Priority::Normal).is_privileged());
    }

    #[test]
    fn priority_and_qos_indices_are_dense() {
        for (i, p) in Priority::ALL.iter().enumerate() {
            assert_eq!(p.index(), i);
        }
        for (i, q) in QosClass::ALL.iter().enumerate() {
            assert_eq!(q.index(), i);
        }
        assert!(QosClass::Interactive.r_hint().unwrap() < QosClass::Batch.r_hint().unwrap());
        assert!(QosClass::Standard.r_hint().is_none());
    }

    #[test]
    fn queue_depth_bound_rejects_and_drain_readmits() {
        let a = AdmissionState::new(1);
        let c = cfg(2, 1_000_000);
        let id1 = a.try_admit(&c, 10, None, false).unwrap();
        let id2 = a.try_admit(&c, 10, None, false).unwrap();
        assert!(id2 > id1, "ids are unique and increasing");
        let (reason, retry) = a.try_admit(&c, 10, None, false).unwrap_err();
        assert_eq!(reason, RejectReason::QueueFull);
        assert!(retry >= RETRY_MIN);
        assert_eq!(a.queued(), (2, 20));
        a.note_cut(1, 10);
        assert!(a.try_admit(&c, 10, None, false).is_ok(), "drain frees a slot");
        let r = a.report();
        assert_eq!((r.admitted, r.rejected_queue_full), (3, 1));
    }

    #[test]
    fn token_bound_rejects_independently_of_seq_bound() {
        let a = AdmissionState::new(1);
        let c = cfg(100, 64);
        a.try_admit(&c, 60, None, false).unwrap();
        let (reason, _) = a.try_admit(&c, 10, None, false).unwrap_err();
        assert_eq!(reason, RejectReason::QueueFull);
        assert!(a.try_admit(&c, 4, None, false).is_ok(), "small request still fits");
    }

    #[test]
    fn projected_deadline_miss_sheds_once_rate_is_known() {
        let a = AdmissionState::new(1);
        let c = cfg(100, 1_000_000);
        // no rate estimate yet: deadline requests are admitted on faith
        a.try_admit(&c, 100, Some(Duration::from_millis(1)), false).unwrap();
        // 1000 tok/s measured; 200 queued tokens ⇒ ~200 ms projected wait
        a.note_service(1000, Duration::from_secs(1));
        let (reason, retry) =
            a.try_admit(&c, 100, Some(Duration::from_millis(50)), false).unwrap_err();
        assert_eq!(reason, RejectReason::DeadlineUnmeetable);
        assert!(retry >= RETRY_MIN && retry <= RETRY_MAX);
        // a lax deadline on the same queue is fine
        assert!(a.try_admit(&c, 100, Some(Duration::from_secs(10)), false).is_ok());
        // no deadline: projected-miss shedding never applies
        assert!(a.try_admit(&c, 100, None, false).is_ok());
        assert_eq!(a.report().rejected_deadline, 1);
    }

    #[test]
    fn projection_scales_with_replica_count() {
        // same queue, same per-replica rate: a 4-replica cluster drains
        // 4× faster, so the deadline that a single replica would miss is
        // comfortably met and must NOT be shed
        let c = cfg(100, 1_000_000);
        let single = AdmissionState::new(1);
        let quad = AdmissionState::new(4);
        for a in [&single, &quad] {
            a.try_admit(&c, 400, None, false).unwrap();
            a.note_service(1000, Duration::from_secs(1)); // 1000 tok/s per replica
        }
        // 500 queued tokens: 1 replica projects 500ms, 4 replicas 125ms
        let ttl = Some(Duration::from_millis(200));
        assert_eq!(
            single.try_admit(&c, 100, ttl, false).unwrap_err().0,
            RejectReason::DeadlineUnmeetable
        );
        assert!(quad.try_admit(&c, 100, ttl, false).is_ok(), "4-replica drain meets the deadline");
    }

    #[test]
    fn projected_miss_can_be_disabled() {
        let a = AdmissionState::new(1);
        let mut c = cfg(100, 1_000_000);
        c.shed_on_projected_miss = false;
        a.note_service(10, Duration::from_secs(1)); // 10 tok/s: everything projects late
        assert!(a.try_admit(&c, 1000, Some(Duration::from_millis(1)), false).is_ok());
    }

    #[test]
    fn blocking_admit_waits_for_drain_and_times_out() {
        let a = AdmissionState::new(1);
        let c = cfg(1, 1_000_000);
        a.try_admit(&c, 10, None, false).unwrap();
        // times out while full
        let err = a.admit_blocking(&c, 10, None, false).unwrap_err();
        assert_eq!(err.0, RejectReason::QueueFull);
        // a concurrent drain unblocks the waiter
        let a2 = a.clone();
        let t = thread::spawn(move || a2.admit_blocking(&cfg(1, 1_000_000), 10, None, false));
        thread::sleep(Duration::from_millis(10));
        a.note_cut(1, 10);
        assert!(t.join().unwrap().is_ok());
    }

    #[test]
    fn service_rate_ewma_smooths() {
        let a = AdmissionState::new(1);
        assert_eq!(a.service_rate_tps(), 0.0);
        a.note_service(100, Duration::from_secs(1));
        assert!((a.service_rate_tps() - 100.0).abs() < 1e-9, "first sample sets the rate");
        a.note_service(200, Duration::from_secs(1));
        let r = a.service_rate_tps();
        assert!(r > 100.0 && r < 200.0, "EWMA between samples: {r}");
        a.note_service(0, Duration::from_secs(1)); // no-op
        assert_eq!(a.service_rate_tps(), r);
    }

    #[test]
    fn shed_accounting_releases_slots_and_counts_cancelled() {
        let a = AdmissionState::new(1);
        let c = cfg(4, 1_000_000);
        for _ in 0..4 {
            a.try_admit(&c, 10, None, false).unwrap();
        }
        a.note_shed_at_cut(2, 20); // two cancelled at the cut
        a.note_cut(1, 10); // one cut into a batch
        a.note_cancelled(1); // …then cancelled late at the replica
        assert_eq!(a.queued(), (1, 10));
        a.note_cut(1, 10);
        a.note_failed(1); // last one's forward errored: no reply
        let r = a.report();
        assert_eq!(r.cancelled, 3);
        assert_eq!(r.failed, 1);
        assert_eq!(r.admitted, 4);
        // every admitted request accounted: 0 responses + 3 cancelled + 1 failed
        assert_eq!(r.unserved(), 4);
    }

    #[test]
    fn class_quota_reserves_slots_for_privileged_traffic() {
        let a = AdmissionState::new(1);
        // 4 slots, 50% reserved: unprivileged traffic is bounded at 2
        let c = AdmissionConfig { privileged_reserve: 0.5, ..cfg(4, 1_000_000) };
        assert_eq!(c.unprivileged_seq_bound(), 2);
        a.try_admit(&c, 10, None, false).unwrap();
        a.try_admit(&c, 10, None, false).unwrap();
        let (reason, retry) = a.try_admit(&c, 10, None, false).unwrap_err();
        assert_eq!(reason, RejectReason::ClassQuota, "Low flood stops at the unreserved share");
        assert!(retry >= RETRY_MIN);
        // privileged traffic still finds the reserved room
        a.try_admit(&c, 10, None, true).unwrap();
        a.try_admit(&c, 10, None, true).unwrap();
        let (reason, _) = a.try_admit(&c, 10, None, true).unwrap_err();
        assert_eq!(reason, RejectReason::QueueFull, "full bound still applies to privileged");
        let r = a.report();
        assert_eq!(r.admitted, 4);
        assert_eq!(r.rejected_quota, 1);
        assert_eq!(r.rejected_queue_full, 1);
        assert_eq!(r.rejected(), 2);
        // drain below the unreserved share re-admits unprivileged traffic
        a.note_cut(3, 30);
        assert!(a.try_admit(&c, 10, None, false).is_ok());
    }

    #[test]
    fn zero_reserve_disables_the_quota_and_keeps_one_slot_floor() {
        let c = cfg(4, 1_000_000);
        assert_eq!(c.unprivileged_seq_bound(), 4, "no reserve: full bound");
        // a 100% reserve still leaves one unprivileged slot (delay, never
        // lock out)
        let all = AdmissionConfig { privileged_reserve: 1.0, ..cfg(4, 1_000_000) };
        assert_eq!(all.unprivileged_seq_bound(), 1);
        let a = AdmissionState::new(1);
        a.try_admit(&all, 10, None, false).unwrap();
        assert_eq!(
            a.try_admit(&all, 10, None, false).unwrap_err().0,
            RejectReason::ClassQuota
        );
    }

    #[test]
    fn burst_admission_decides_in_order_under_one_lock() {
        let a = AdmissionState::new(1);
        let c = cfg(3, 1_000_000);
        let out = a.try_admit_burst(&c, &[args(10, false); 5]);
        assert_eq!(out.len(), 5);
        let ids: Vec<u64> = out[..3].iter().map(|r| *r.as_ref().unwrap()).collect();
        assert!(ids.windows(2).all(|w| w[1] == w[0] + 1), "ids dense in item order: {ids:?}");
        for r in &out[3..] {
            assert_eq!(r.as_ref().unwrap_err().0, RejectReason::QueueFull, "overflow shed");
        }
        assert_eq!(a.queued(), (3, 30));
        let r = a.report();
        assert_eq!((r.admitted, r.rejected_queue_full), (3, 2));
        // an empty burst is a no-op
        assert!(a.try_admit_burst(&c, &[]).is_empty());
    }

    #[test]
    fn auto_reserve_tracks_the_privileged_share() {
        let a = AdmissionState::new(1);
        let c = AdmissionConfig { auto_reserve: true, ..cfg(100, 1_000_000) };
        assert!(a.privileged_share().is_none(), "no samples yet");
        // all-batch mix: the EWMA decays toward 0 from the 0.0 prior, so
        // the quota never engages below the full bound
        for _ in 0..50 {
            a.try_admit(&c, 1, None, false).unwrap();
        }
        let share = a.privileged_share().unwrap();
        assert!(share < 0.05, "batch-only mix drives the reserve down: {share}");
        // swing to all-interactive: the share climbs and unprivileged
        // arrivals start being quota-shed while privileged still fit
        for _ in 0..200 {
            let _ = a.try_admit(&c, 1, None, true);
        }
        let share = a.privileged_share().unwrap();
        assert!(share > 0.9, "interactive swing lifts the share: {share}");
        // drain below the full bound but above the unreserved share: the
        // quota (not queue-full) is what sheds unprivileged traffic now
        a.note_cut(80, 80);
        let (reason, _) = a.try_admit(&c, 1, None, false).unwrap_err();
        assert_eq!(reason, RejectReason::ClassQuota, "reserve now protects interactive slots");
        // the static knob still rules when auto_reserve is off
        let s = AdmissionState::new(1);
        let fixed = cfg(100, 1_000_000);
        for _ in 0..99 {
            s.try_admit(&fixed, 1, None, true).unwrap();
        }
        let ok = s.try_admit(&fixed, 1, None, false).is_ok();
        assert!(ok, "no quota without auto/static reserve");
    }

    #[test]
    fn auto_reserve_seeds_from_the_static_prior_and_stays_capped() {
        let a = AdmissionState::new(1);
        let c = AdmissionConfig {
            auto_reserve: true,
            privileged_reserve: 0.5,
            ..cfg(4, 1_000_000)
        };
        // first decision: EWMA ≈ the 0.5 prior ⇒ unprivileged bound 2,
        // same as the static knob would give
        a.try_admit(&c, 1, None, false).unwrap();
        let share = a.privileged_share().unwrap();
        assert!((share - 0.475).abs() < 1e-9, "seeded from the prior: {share}");
        // a long all-privileged run saturates at the cap, never 1.0-locks
        // unprivileged traffic out (bound floors at 1 slot via the clamp)
        let b = AdmissionState::new(1);
        let big = AdmissionConfig { auto_reserve: true, ..cfg(10, 1_000_000) };
        for _ in 0..500 {
            let _ = b.try_admit(&big, 1, None, true);
        }
        assert_eq!(big.unprivileged_seq_bound_for(MAX_AUTO_RESERVE), 1);
        // queue is full of privileged work; drain it all, then an
        // unprivileged request still finds its floor slot
        b.note_cut(10, 10);
        assert!(b.try_admit(&big, 1, None, false).is_ok(), "floor slot survives the cap");
    }

    #[test]
    fn blocking_admit_waits_out_a_quota_rejection() {
        let a = AdmissionState::new(1);
        let c = AdmissionConfig { privileged_reserve: 0.5, ..cfg(2, 1_000_000) };
        a.try_admit(&c, 10, None, false).unwrap();
        // unprivileged bound is 1: blocking submit times out while held
        let err = a.admit_blocking(&c, 10, None, false).unwrap_err();
        assert_eq!(err.0, RejectReason::ClassQuota);
        // a drain unblocks the quota waiter like a queue-full waiter
        let a2 = a.clone();
        let c2 = c;
        let t = thread::spawn(move || a2.admit_blocking(&c2, 10, None, false));
        thread::sleep(Duration::from_millis(10));
        a.note_cut(1, 10);
        assert!(t.join().unwrap().is_ok());
    }

    #[test]
    fn generation_ticket_streams_then_suppresses_after_cancel() {
        let (tx, rx) = mpsc::channel();
        let (stx, srx) = mpsc::channel();
        let ticket =
            Ticket { rx, cancel: Arc::new(AtomicBool::new(false)), id: 9, stream: Some(srx) };
        assert!(ticket.is_generation());
        assert!(ticket.try_next_event().is_none(), "nothing landed yet");
        stx.send(StreamEvent::Token { token: 7, index: 0 }).unwrap();
        stx.send(StreamEvent::Token { token: 8, index: 1 }).unwrap();
        stx.send(StreamEvent::Done { reason: FinishReason::Length, generated: 2 }).unwrap();
        let (tokens, reason) = ticket.collect_tokens(Duration::from_millis(10)).unwrap();
        assert_eq!(tokens, vec![7, 8]);
        assert_eq!(reason, FinishReason::Length);
        // a raced event after cancel is never surfaced
        stx.send(StreamEvent::Token { token: 9, index: 2 }).unwrap();
        ticket.cancel();
        assert!(ticket.try_next_event().is_none());
        assert!(ticket.wait_event(Duration::from_millis(1)).is_err());
        drop(tx);
    }

    #[test]
    fn scoring_ticket_has_no_stream() {
        let (_tx, rx) = mpsc::channel();
        let ticket = Ticket { rx, cancel: Arc::new(AtomicBool::new(false)), id: 3, stream: None };
        assert!(!ticket.is_generation());
        assert!(ticket.try_next_event().is_none());
        assert!(ticket.wait_event(Duration::from_millis(1)).is_err());
    }

    #[test]
    fn abort_rolls_back_an_admission() {
        let a = AdmissionState::new(1);
        let c = cfg(4, 100);
        let id = a.try_admit(&c, 10, None, false).unwrap();
        a.abort_admit(id, 10);
        assert_eq!(a.queued(), (0, 0));
        assert_eq!(a.report().admitted, 0);
    }

    #[test]
    fn trace_records_admits_rejects_and_abort_terminals() {
        let a = AdmissionState::new(1);
        a.enable_trace(TraceClock::new(), TraceConfig::on());
        let c = cfg(1, 1_000_000);
        let id = a.try_admit_for(&c, 10, None, false, "interactive", "high").unwrap();
        let (reason, _, rid) =
            a.try_admit_for(&c, 10, None, false, "standard", "normal").unwrap_err();
        assert_eq!(reason, RejectReason::QueueFull);
        assert!(rid > id, "rejections are assigned ids too");
        a.abort_admit(id, 10);
        let (events, dropped) = a.take_trace();
        assert_eq!(dropped, 0);
        assert_eq!(events.len(), 3, "admit + reject + abort terminal");
        assert!(matches!(
            events[0].kind,
            EventKind::Admitted { qos: "interactive", priority: "high", tokens: 10 }
        ));
        assert_eq!(events[0].req, id);
        assert!(matches!(events[1].kind, EventKind::Rejected { reason: "queue-full" }));
        assert_eq!(events[1].req, rid);
        assert!(events[2].kind.is_terminal());
        assert_eq!(events[2].req, id);
        // untraced by default: the disabled collector records nothing
        let b = AdmissionState::new(1);
        b.try_admit(&c, 10, None, false).unwrap();
        assert!(b.take_trace().0.is_empty());
    }

    #[test]
    fn ticket_cancel_suppresses_a_raced_response() {
        let (tx, rx) = mpsc::channel();
        let ticket = Ticket { rx, cancel: Arc::new(AtomicBool::new(false)), id: 7, stream: None };
        assert_eq!(ticket.id(), 7);
        assert!(ticket.poll().is_none(), "pending");
        // a response lands, then the cancel races in
        tx.send(Response {
            next_token: 1,
            mean_nll: 0.5,
            latency: Duration::from_millis(1),
            queue_wait: Duration::from_millis(0),
            generation: 0,
        })
        .unwrap();
        ticket.cancel();
        assert!(ticket.is_cancelled());
        assert!(ticket.poll().is_none(), "cancelled ticket never yields a response");
        assert!(ticket.wait().is_err());
        assert!(ticket.wait_timeout(Duration::from_millis(1)).is_err());
    }

    #[test]
    fn ticket_waits_deliver_and_closed_channel_errors() {
        let (tx, rx) = mpsc::channel();
        let ticket = Ticket { rx, cancel: Arc::new(AtomicBool::new(false)), id: 1, stream: None };
        tx.send(Response {
            next_token: 9,
            mean_nll: 1.0,
            latency: Duration::from_millis(2),
            queue_wait: Duration::from_millis(1),
            generation: 3,
        })
        .unwrap();
        let resp = ticket.wait().unwrap();
        assert_eq!(resp.next_token, 9);
        drop(tx);
        assert!(ticket.wait().is_err(), "dropped sender reads as cancelled/closed");
    }
}
