//! Hardware-aware bitwidth allocation (§4.2) — MxMoE's core algorithm.
//!
//! Pipeline:
//! 1. [`calibrate`] runs the fp32 model over calibration sequences,
//!    collecting per-expert activation frequencies, per-linear-block inputs
//!    (GPTQ Hessians) and the MoE-block inputs.
//! 2. [`sensitivity`] measures Δ_{i,j,k} (Eq. 6): the output distortion of
//!    quantizing one linear block with one scheme.
//! 3. [`mckp`] solves the allocation ILP (Eq. 7): pick one scheme per linear
//!    block minimizing `L^r · T^(1−r)` under the weight-memory budget,
//!    where `T` is the tile-level runtime model of §4.2.2.

pub mod calibrate;
pub mod mckp;
pub mod sensitivity;

pub use calibrate::{calibrate, CalibrationStats, LayerStats};
pub use mckp::{solve_mckp, solve_mckp_warm, Granularity, Item, McKpGroup, Solution};
pub use sensitivity::{measure_sensitivity, SensitivityTable};

use anyhow::Result;

use crate::costmodel::gpu::GpuSpec;
use crate::costmodel::micro::Specialization;
use crate::costmodel::tile::best_tile;
use crate::moe::{ModelConfig, MoeLm};
use crate::quant::scheme::{QuantScheme, SchemeRegistry};
use crate::ser::Json;

/// A complete mixed-precision assignment: `schemes[layer_pos][expert][linear]`
/// where `layer_pos` indexes the model's MoE layers in order and `expert`
/// covers routed then shared experts.
#[derive(Clone, Debug)]
pub struct Allocation {
    /// Transformer layer indices of each MoE block (parallel to `schemes`).
    pub layers: Vec<usize>,
    pub schemes: Vec<Vec<[QuantScheme; 3]>>,
}

impl Allocation {
    /// Uniform assignment across all blocks.
    pub fn uniform(cfg: &ModelConfig, scheme: QuantScheme) -> Allocation {
        let total = cfg.n_experts + cfg.n_shared;
        Allocation {
            layers: cfg.moe_layers(),
            schemes: cfg
                .moe_layers()
                .iter()
                .map(|_| vec![[scheme; 3]; total])
                .collect(),
        }
    }

    /// Average stored weight bits over all allocated linear blocks.
    pub fn avg_weight_bits(&self, cfg: &ModelConfig) -> f64 {
        let mut bits = 0.0;
        let mut elems = 0.0;
        for block in &self.schemes {
            for ex in block {
                for (j, s) in ex.iter().enumerate() {
                    let (n, k) = if j == 2 { (cfg.hidden, cfg.inter) } else { (cfg.inter, cfg.hidden) };
                    bits += s.avg_weight_bits(k) * (n * k) as f64;
                    elems += (n * k) as f64;
                }
            }
        }
        bits / elems
    }

    /// Average activation bits (weighted by activation frequency would be
    /// more precise; we report the unweighted mean like the paper's `aX.Y`).
    pub fn avg_act_bits(&self, cfg: &ModelConfig) -> f64 {
        let mut bits = 0.0;
        let mut n = 0.0;
        for block in &self.schemes {
            for ex in block {
                for (j, s) in ex.iter().enumerate() {
                    let k = if j == 2 { cfg.inter } else { cfg.hidden };
                    bits += s.avg_act_bits(k);
                    n += 1.0;
                }
            }
        }
        bits / n
    }

    /// Tab. 7-style dump: per (layer, expert) the three linears' schemes.
    pub fn to_json(&self) -> Json {
        let blocks: Vec<Json> = self
            .layers
            .iter()
            .zip(&self.schemes)
            .map(|(l, experts)| {
                let rows: Vec<Json> = experts
                    .iter()
                    .enumerate()
                    .map(|(e, schemes)| {
                        Json::obj(vec![
                            ("expert", Json::num(e as f64)),
                            ("gate", Json::str(&schemes[0].name())),
                            ("up", Json::str(&schemes[1].name())),
                            ("down", Json::str(&schemes[2].name())),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("layer", Json::num(*l as f64)),
                    ("experts", Json::Arr(rows)),
                ])
            })
            .collect();
        Json::Arr(blocks)
    }
}

/// Allocator configuration.
#[derive(Clone, Debug)]
pub struct AllocatorConfig {
    /// Accuracy/performance trade-off exponent (Eq. 3's `r`; 1 = accuracy only).
    pub r: f64,
    /// Target average stored weight bits (e.g. 2.25, 3.25, 5.0).
    pub target_avg_bits: f64,
    /// Allocation granularity (Tab. 3 ablation).
    pub granularity: Granularity,
    /// Reference batch size for the runtime model (tokens entering a block).
    pub batch_tokens: usize,
}

impl Default for AllocatorConfig {
    fn default() -> Self {
        AllocatorConfig {
            r: 0.75,
            target_avg_bits: 5.0,
            granularity: Granularity::LinearBlock,
            batch_tokens: 512,
        }
    }
}

/// Normalized per-layer routed-expert activation frequencies from a
/// calibration pass — the offline workload vector the allocator weights
/// the runtime model by, and the drift baseline the online telemetry
/// compares live traffic against ([`crate::serve::telemetry`]).
pub fn activation_frequencies(stats: &CalibrationStats) -> Vec<Vec<f64>> {
    stats
        .layers
        .iter()
        .map(|ls| {
            let total: usize = ls.activation_counts.iter().sum();
            ls.activation_counts
                .iter()
                .map(|&c| c as f64 / total.max(1) as f64)
                .collect()
        })
        .collect()
}

/// Build the MCKP groups from calibration + sensitivity + the runtime cost
/// model, then solve. One group per linear block (or per expert at
/// expert-level granularity) across *all* MoE layers; the budget is global.
pub fn allocate(
    lm: &MoeLm,
    gpu: &GpuSpec,
    registry: &SchemeRegistry,
    stats: &CalibrationStats,
    sens: &SensitivityTable,
    cfg: &AllocatorConfig,
) -> Result<Allocation> {
    allocate_with_frequencies(
        &lm.cfg,
        gpu,
        registry,
        sens,
        &activation_frequencies(stats),
        cfg,
        None,
    )
}

/// The allocator core, parameterized by the per-layer routed-expert
/// activation-frequency vectors instead of full calibration stats. This is
/// the entry point the online replanner uses: live telemetry frequencies
/// replace the calibration histogram (the paper's §3 insight — activation
/// frequency shapes the optimal mixed-precision configuration — tracked at
/// serve time), and `warm` seeds the solver with the currently-serving
/// plan so the re-solve is incremental and never regresses under the new
/// weights.
pub fn allocate_with_frequencies(
    model: &ModelConfig,
    gpu: &GpuSpec,
    registry: &SchemeRegistry,
    sens: &SensitivityTable,
    freqs: &[Vec<f64>],
    cfg: &AllocatorConfig,
    warm: Option<&Allocation>,
) -> Result<Allocation> {
    let layers = model.moe_layers();
    if freqs.len() != layers.len() {
        anyhow::bail!(
            "allocate: {} frequency vectors for {} MoE layers",
            freqs.len(),
            layers.len()
        );
    }
    if let Some(bad) = freqs.iter().position(|f| f.len() != model.n_experts) {
        anyhow::bail!(
            "allocate: layer {bad} frequency vector has {} entries, model has {} routed experts",
            freqs[bad].len(),
            model.n_experts
        );
    }
    let total_experts = model.n_experts + model.n_shared;
    let mut groups: Vec<McKpGroup> = Vec::new();

    for (bi, layer_freqs) in freqs.iter().enumerate() {
        // tokens each expert sees at the reference batch size
        let m_of = |e: usize| -> usize {
            if e >= model.n_experts {
                return cfg.batch_tokens; // shared experts see every token
            }
            let frac = layer_freqs[e];
            ((frac * cfg.batch_tokens as f64 * model.topk as f64).round() as usize).max(1)
        };
        for e in 0..total_experts {
            let m = m_of(e);
            let mut items_per_linear: Vec<Vec<Item>> = Vec::with_capacity(3);
            for j in 0..3 {
                let (n, k) = if j == 2 {
                    (model.hidden, model.inter)
                } else {
                    (model.inter, model.hidden)
                };
                let items: Vec<Item> = registry
                    .schemes
                    .iter()
                    .map(|s| {
                        let (cost, _) =
                            best_tile(gpu, s, m, n, k, None, Specialization::Specialized);
                        Item {
                            scheme: *s,
                            delta: sens.delta(bi, e, j, s),
                            // the ILP's T contribution: Σ tile costs / P
                            time: cost / gpu.sms as f64,
                            bytes: s.weight_bytes(n, k) as f64,
                        }
                    })
                    .collect();
                items_per_linear.push(items);
            }
            match cfg.granularity {
                Granularity::LinearBlock => {
                    for (j, items) in items_per_linear.into_iter().enumerate() {
                        groups.push(McKpGroup { block: bi, expert: e, linear: j, items });
                    }
                }
                Granularity::Expert => {
                    // one choice for the whole expert: sum the three linears
                    let items: Vec<Item> = (0..registry.schemes.len())
                        .map(|si| Item {
                            scheme: registry.schemes[si],
                            delta: items_per_linear.iter().map(|v| v[si].delta).sum(),
                            time: items_per_linear.iter().map(|v| v[si].time).sum(),
                            bytes: items_per_linear.iter().map(|v| v[si].bytes).sum(),
                        })
                        .collect();
                    groups.push(McKpGroup { block: bi, expert: e, linear: 3, items });
                }
            }
        }
    }

    // budget: target average bits over all weight elements
    let total_elems =
        freqs.len() as f64 * (total_experts * 3) as f64 * (model.inter * model.hidden) as f64;
    let budget_bytes = cfg.target_avg_bits * total_elems / 8.0;

    let warm_choices = warm.and_then(|a| warm_start_choices(&groups, a));
    let sol = solve_mckp_warm(&groups, cfg.r, budget_bytes, warm_choices.as_deref())?;

    // materialize the allocation
    let mut schemes = vec![vec![[QuantScheme::FP16; 3]; total_experts]; freqs.len()];
    for (g, &choice) in groups.iter().zip(&sol.choices) {
        let s = g.items[choice].scheme;
        if g.linear == 3 {
            schemes[g.block][g.expert] = [s, s, s];
        } else {
            schemes[g.block][g.expert][g.linear] = s;
        }
    }
    Ok(Allocation { layers, schemes })
}

/// Map an existing allocation onto the freshly-built groups' item indices
/// (the MCKP warm start). Returns `None` when any group has no item with
/// the incumbent's scheme — e.g. the incumbent was built from a different
/// registry — in which case the solve runs cold.
fn warm_start_choices(groups: &[McKpGroup], warm: &Allocation) -> Option<Vec<usize>> {
    groups
        .iter()
        .map(|g| {
            let linear = if g.linear == 3 { 0 } else { g.linear };
            let scheme = *warm
                .schemes
                .get(g.block)?
                .get(g.expert)?
                .get(linear)?;
            g.items.iter().position(|i| i.scheme == scheme)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_allocation_bits() {
        let cfg = ModelConfig::qwen15_mini();
        let a = Allocation::uniform(&cfg, QuantScheme::W4A16G128);
        // gate/up (k=128) amortize to 4.25; down (k=64) clamps g128→g64
        // giving 4.5; weight-elements are equal thirds ⇒ 4.333 overall
        assert!((a.avg_weight_bits(&cfg) - (4.25 * 2.0 + 4.5) / 3.0).abs() < 1e-9);
        let a8 = Allocation::uniform(&cfg, QuantScheme::W8A8);
        assert!(a8.avg_weight_bits(&cfg) > 8.0);
        assert!(a8.avg_act_bits(&cfg) < 8.2);
    }

    #[test]
    fn allocation_json_has_all_experts() {
        let cfg = ModelConfig::mixtral_mini();
        let a = Allocation::uniform(&cfg, QuantScheme::W4A4);
        let j = a.to_json();
        let blocks = j.as_arr().unwrap();
        assert_eq!(blocks.len(), cfg.moe_layers().len());
        assert_eq!(blocks[0].get("experts").unwrap().as_arr().unwrap().len(), 8);
    }
}
