//! Quantization sensitivity Δ_{i,j,k} (Eq. 6): the L2 output distortion of
//! an MoE block when exactly one linear block is quantized with one scheme.
//!
//! Efficient form: the block output is a sum of per-expert contributions,
//! so quantizing one linear of expert `i` only changes expert `i`'s
//! contribution — we compute each expert's fp32 output once and re-run only
//! the perturbed expert per scheme.

use anyhow::Result;

use crate::moe::block::{LinearKind, MoeBlock};
use crate::moe::lm::Ffn;
use crate::moe::MoeLm;
use crate::quant::scheme::{QuantScheme, SchemeRegistry};
use crate::quant::uniform::{fake_quant_matrix, fake_quant_rows_act};
use crate::tensor::matrix::matmul_nt;
use crate::tensor::ops::silu;
use crate::tensor::Matrix;
use crate::util::threadpool::parallel_for;

use super::calibrate::CalibrationStats;

/// Δ values indexed `[block][expert][linear][scheme]` (scheme order follows
/// the registry used at measurement).
pub struct SensitivityTable {
    pub schemes: Vec<QuantScheme>,
    pub delta: Vec<Vec<[Vec<f64>; 3]>>,
}

impl SensitivityTable {
    /// Δ for (block, expert, linear, scheme); fp16/unknown schemes are 0.
    pub fn delta(&self, block: usize, expert: usize, linear: usize, s: &QuantScheme) -> f64 {
        if s.is_fp16() {
            return 0.0;
        }
        match self.schemes.iter().position(|x| x == s) {
            Some(si) => self.delta[block][expert][linear][si],
            None => 0.0,
        }
    }
}

/// Quantized forward of one expert with exactly one linear perturbed.
fn expert_forward_one_quant(
    block: &MoeBlock,
    expert: usize,
    x: &Matrix,
    kind: LinearKind,
    s: &QuantScheme,
) -> Matrix {
    let e = block.expert_at(expert);
    let quant_w = |w: &Matrix| fake_quant_matrix(w, s.wbits, s.wgroup, s.wsym);
    let maybe = |w: &Matrix, k: LinearKind| if k == kind { quant_w(w) } else { w.clone() };
    let gate = maybe(&e.gate, LinearKind::Gate);
    let up = maybe(&e.up, LinearKind::Up);
    let down = maybe(&e.down, LinearKind::Down);
    let x_g = if kind == LinearKind::Gate { fake_quant_rows_act(x, s.abits, s.agroup) } else { x.clone() };
    let x_u = if kind == LinearKind::Up { fake_quant_rows_act(x, s.abits, s.agroup) } else { x.clone() };
    let g = matmul_nt(&x_g, &gate);
    let u = matmul_nt(&x_u, &up);
    let mut h = Matrix::zeros(g.rows, g.cols);
    for i in 0..g.data.len() {
        h.data[i] = silu(g.data[i]) * u.data[i];
    }
    let h_in = if kind == LinearKind::Down { fake_quant_rows_act(&h, s.abits, s.agroup) } else { h };
    matmul_nt(&h_in, &down)
}

/// Measure the full sensitivity table over the calibration inputs.
pub fn measure_sensitivity(
    lm: &MoeLm,
    stats: &CalibrationStats,
    registry: &SchemeRegistry,
) -> Result<SensitivityTable> {
    let cfg = &lm.cfg;
    let total_experts = cfg.n_experts + cfg.n_shared;
    let schemes: Vec<QuantScheme> =
        registry.schemes.iter().copied().filter(|s| !s.is_fp16()).collect();
    let mut table: Vec<Vec<[Vec<f64>; 3]>> = Vec::with_capacity(stats.layers.len());

    for ls in &stats.layers {
        let block = match &lm.layers[ls.layer].ffn {
            Ffn::Moe(b) => b,
            Ffn::Dense(_) => unreachable!(),
        };
        let x = &ls.moe_inputs;
        let routing = crate::moe::route(x, &block.w_router, block.topk);
        // fp32 contribution of each expert (weighted outputs on its tokens)
        let mut fp32_out: Vec<Matrix> = Vec::with_capacity(total_experts);
        let mut token_sets: Vec<(Vec<usize>, Vec<f32>)> = Vec::with_capacity(total_experts);
        for e in 0..total_experts {
            if e < cfg.n_experts {
                let (tokens, weights) = &routing.per_expert[e];
                let xe = x.gather_rows(tokens);
                fp32_out.push(block.expert_at(e).forward(&xe));
                token_sets.push((tokens.clone(), weights.clone()));
            } else {
                fp32_out.push(block.expert_at(e).forward(x));
                token_sets.push(((0..x.rows).collect(), vec![1.0; x.rows]));
            }
        }

        // Δ for every (expert, linear, scheme) in parallel
        let mut layer_table: Vec<[Vec<f64>; 3]> = (0..total_experts)
            .map(|_| {
                [
                    vec![0.0; schemes.len()],
                    vec![0.0; schemes.len()],
                    vec![0.0; schemes.len()],
                ]
            })
            .collect();
        {
            let n_schemes = schemes.len();
            let cells: Vec<(usize, usize, usize)> = (0..total_experts)
                .flat_map(|e| {
                    (0..3usize).flat_map(move |j| (0..n_schemes).map(move |si| (e, j, si)))
                })
                .collect();
            let results: Vec<std::sync::Mutex<f64>> =
                cells.iter().map(|_| std::sync::Mutex::new(0.0)).collect();
            parallel_for(cells.len(), |ci| {
                let (e, j, si) = cells[ci];
                let (tokens, weights) = &token_sets[e];
                if tokens.is_empty() {
                    return;
                }
                let xe = x.gather_rows(tokens);
                let kind = LinearKind::ALL[j];
                let yq = expert_forward_one_quant(block, e, &xe, kind, &schemes[si]);
                // Δ = || (ŷ − y) ⊙ gate_weights ||₂ over this expert's tokens
                let mut d2 = 0.0f64;
                for (t, &w) in weights.iter().enumerate() {
                    for c in 0..yq.cols {
                        let diff = ((yq.at(t, c) - fp32_out[e].at(t, c)) * w) as f64;
                        d2 += diff * diff;
                    }
                }
                *results[ci].lock().unwrap() = d2.sqrt();
            });
            for (ci, &(e, j, si)) in cells.iter().enumerate() {
                layer_table[e][j][si] = *results[ci].lock().unwrap();
            }
        }
        table.push(layer_table);
    }

    Ok(SensitivityTable { schemes, delta: table })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::calibrate::calibrate;
    use crate::moe::ModelConfig;
    use crate::util::Rng;

    fn setup() -> (MoeLm, CalibrationStats) {
        let cfg = ModelConfig {
            name: "tiny".into(),
            vocab: 32,
            hidden: 32,
            layers: 2,
            heads: 2,
            n_experts: 6,
            n_shared: 1,
            topk: 2,
            inter: 16,
            dense_first: false,
            seq_len: 16,
        };
        let mut rng = Rng::new(150);
        let lm = MoeLm::random(&cfg, &mut rng);
        let seqs: Vec<Vec<u32>> = (0..6)
            .map(|_| (0..16).map(|_| rng.below(32) as u32).collect())
            .collect();
        let refs: Vec<&[u32]> = seqs.iter().map(|s| s.as_slice()).collect();
        let stats = calibrate(&lm, &refs, None).unwrap();
        (lm, stats)
    }

    #[test]
    fn sensitivity_monotone_in_bits() {
        let (lm, stats) = setup();
        let reg = SchemeRegistry {
            schemes: vec![QuantScheme::W2A16, QuantScheme::W4A16, QuantScheme::W8A16],
        };
        let t = measure_sensitivity(&lm, &stats, &reg).unwrap();
        let mut checked = 0;
        for b in 0..t.delta.len() {
            for e in 0..t.delta[b].len() {
                for j in 0..3 {
                    let d2 = t.delta(b, e, j, &QuantScheme::W2A16);
                    let d4 = t.delta(b, e, j, &QuantScheme::W4A16);
                    let d8 = t.delta(b, e, j, &QuantScheme::W8A16);
                    if d2 == 0.0 {
                        continue; // expert saw no tokens
                    }
                    assert!(d2 > d4 && d4 > d8, "({b},{e},{j}): {d2} {d4} {d8}");
                    checked += 1;
                }
            }
        }
        assert!(checked > 10);
    }

    #[test]
    fn sensitivity_heterogeneous_across_blocks() {
        // Fig. 1a: the spread across linear blocks must be substantial
        let (lm, stats) = setup();
        let reg = SchemeRegistry { schemes: vec![QuantScheme::W4A4] };
        let t = measure_sensitivity(&lm, &stats, &reg).unwrap();
        let mut deltas: Vec<f64> = Vec::new();
        for e in 0..t.delta[0].len() {
            for j in 0..3 {
                let d = t.delta(0, e, j, &QuantScheme::W4A4);
                if d > 0.0 {
                    deltas.push(d);
                }
            }
        }
        let max = deltas.iter().cloned().fold(0.0, f64::max);
        let min = deltas.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min > 1.5, "sensitivity too homogeneous: {min}..{max}");
    }

    #[test]
    fn fp16_has_zero_delta() {
        let (lm, stats) = setup();
        let reg = SchemeRegistry { schemes: vec![QuantScheme::W4A4] };
        let t = measure_sensitivity(&lm, &stats, &reg).unwrap();
        assert_eq!(t.delta(0, 0, 0, &QuantScheme::FP16), 0.0);
    }
}
