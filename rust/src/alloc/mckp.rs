//! The allocation ILP (Eq. 7) as a multiple-choice knapsack.
//!
//! Exactly one scheme per linear block (group), minimize `L^r · T^(1−r)`
//! subject to `Σ bytes ≤ budget`. The objective is non-linear but monotone
//! in both `L = Σ Δ` and `T = Σ c/P`, so we sweep a scalarization weight λ
//! and solve each linear MCKP `min λ·L̂ + (1−λ)·T̂` by Lagrangian relaxation
//! of the memory constraint (bisection on the multiplier — each evaluation
//! is a per-group argmin, so the whole solve is near-linear), followed by a
//! greedy budget-slack repair. The best feasible solution under the true
//! objective wins. An exact exponential solver validates optimality on
//! small instances in tests.

use anyhow::{bail, Result};

use crate::quant::scheme::QuantScheme;

/// One scheme choice for one linear block.
#[derive(Clone, Copy, Debug)]
pub struct Item {
    pub scheme: QuantScheme,
    /// Δ_{i,j,k} — quantization loss contribution.
    pub delta: f64,
    /// Runtime contribution (Σ best-tile cost / P), seconds.
    pub time: f64,
    /// Stored weight bytes.
    pub bytes: f64,
}

/// A group = one linear block (or one expert at expert granularity).
#[derive(Clone, Debug)]
pub struct McKpGroup {
    pub block: usize,
    pub expert: usize,
    /// 0/1/2 = gate/up/down; 3 = whole-expert group.
    pub linear: usize,
    pub items: Vec<Item>,
}

/// Allocation granularity (Tab. 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    LinearBlock,
    Expert,
}

/// Solver output.
#[derive(Clone, Debug)]
pub struct Solution {
    pub choices: Vec<usize>,
    pub l: f64,
    pub t: f64,
    pub bytes: f64,
    pub objective: f64,
}

fn evaluate(groups: &[McKpGroup], choices: &[usize], r: f64) -> Solution {
    let mut l = 0.0;
    let mut t = 0.0;
    let mut bytes = 0.0;
    for (g, &c) in groups.iter().zip(choices) {
        l += g.items[c].delta;
        t += g.items[c].time;
        bytes += g.items[c].bytes;
    }
    Solution { choices: choices.to_vec(), l, t, bytes, objective: objective(l, t, r) }
}

/// `L^r · T^(1−r)` with an epsilon guard (L can be 0 if everything stays fp16).
pub fn objective(l: f64, t: f64, r: f64) -> f64 {
    l.max(1e-12).powf(r) * t.max(1e-12).powf(1.0 - r)
}

/// Per-group argmin of `cost + μ·bytes`.
fn lagrangian_pick(groups: &[McKpGroup], costs: &[Vec<f64>], mu: f64) -> Vec<usize> {
    groups
        .iter()
        .enumerate()
        .map(|(gi, g)| {
            let mut best = 0;
            let mut best_v = f64::INFINITY;
            for (i, item) in g.items.iter().enumerate() {
                let v = costs[gi][i] + mu * item.bytes;
                if v < best_v {
                    best_v = v;
                    best = i;
                }
            }
            best
        })
        .collect()
}

fn total_bytes(groups: &[McKpGroup], choices: &[usize]) -> f64 {
    groups.iter().zip(choices).map(|(g, &c)| g.items[c].bytes).sum()
}

/// Greedy repair: spend leftover budget on the largest scalar-cost
/// reductions per extra byte.
fn greedy_upgrade(groups: &[McKpGroup], costs: &[Vec<f64>], choices: &mut [usize], budget: f64) {
    let mut used = total_bytes(groups, choices);
    loop {
        let mut best: Option<(usize, usize, f64)> = None; // (group, item, gain/byte)
        for (gi, g) in groups.iter().enumerate() {
            let cur = choices[gi];
            for (i, item) in g.items.iter().enumerate() {
                let extra = item.bytes - g.items[cur].bytes;
                let gain = costs[gi][cur] - costs[gi][i];
                if gain <= 0.0 || used + extra > budget {
                    continue;
                }
                let rate = if extra <= 0.0 { f64::INFINITY } else { gain / extra };
                if best.map_or(true, |(_, _, r)| rate > r) {
                    best = Some((gi, i, rate));
                }
            }
        }
        match best {
            Some((gi, i, _)) => {
                used += groups[gi].items[i].bytes - groups[gi].items[choices[gi]].bytes;
                choices[gi] = i;
            }
            None => break,
        }
    }
}

/// Solve the allocation MCKP. `r` ∈ [0,1]; `budget` in bytes.
pub fn solve_mckp(groups: &[McKpGroup], r: f64, budget: f64) -> Result<Solution> {
    solve_mckp_warm(groups, r, budget, None)
}

/// [`solve_mckp`] with an optional warm start: `warm` is an incumbent
/// choice vector (e.g. the currently-serving plan when the online replanner
/// re-solves under drifted activation frequencies). The incumbent seeds the
/// candidate pool and is greedily upgraded under every λ, which guarantees
/// the returned plan is never worse than the incumbent *under the new
/// weights* — the online loop's monotone-improvement property. An invalid
/// or budget-infeasible incumbent is ignored.
pub fn solve_mckp_warm(
    groups: &[McKpGroup],
    r: f64,
    budget: f64,
    warm: Option<&[usize]>,
) -> Result<Solution> {
    if groups.is_empty() {
        bail!("solve_mckp: no groups");
    }
    let warm = warm.filter(|w| {
        w.len() == groups.len()
            && w.iter().zip(groups).all(|(&c, g)| c < g.items.len())
            && total_bytes(groups, w) <= budget * (1.0 + 1e-9)
    });
    // feasibility: even the smallest-bytes choice must fit
    let min_bytes: f64 = groups
        .iter()
        .map(|g| g.items.iter().map(|i| i.bytes).fold(f64::INFINITY, f64::min))
        .sum();
    if min_bytes > budget {
        bail!("infeasible: minimum storage {min_bytes:.0} B exceeds budget {budget:.0} B");
    }
    // normalization scales so λ spans the trade-off meaningfully
    let l_scale = groups
        .iter()
        .map(|g| g.items.iter().map(|i| i.delta).fold(f64::INFINITY, f64::min))
        .sum::<f64>()
        .max(1e-12);
    let t_scale = groups
        .iter()
        .map(|g| g.items.iter().map(|i| i.time).fold(f64::INFINITY, f64::min))
        .sum::<f64>()
        .max(1e-12);

    let mut best: Option<Solution> = warm.map(|w| evaluate(groups, w, r));
    // λ sweep includes the pure-accuracy (r=1-ish) and pure-speed ends
    let lambdas: Vec<f64> = if r >= 1.0 {
        vec![1.0]
    } else if r <= 0.0 {
        vec![0.0]
    } else {
        (0..=10).map(|i| i as f64 / 10.0).collect()
    };
    for &lambda in &lambdas {
        let costs: Vec<Vec<f64>> = groups
            .iter()
            .map(|g| {
                g.items
                    .iter()
                    .map(|i| lambda * i.delta / l_scale + (1.0 - lambda) * i.time / t_scale)
                    .collect()
            })
            .collect();
        // μ = 0 first
        let mut choices = lagrangian_pick(groups, &costs, 0.0);
        if total_bytes(groups, &choices) > budget {
            // bisect μ to meet the budget
            let mut lo = 0.0f64;
            let mut hi = 1e-6;
            while total_bytes(groups, &lagrangian_pick(groups, &costs, hi)) > budget {
                hi *= 4.0;
                if hi > 1e12 {
                    bail!("budget bisection diverged");
                }
            }
            for _ in 0..60 {
                let mid = 0.5 * (lo + hi);
                if total_bytes(groups, &lagrangian_pick(groups, &costs, mid)) > budget {
                    lo = mid;
                } else {
                    hi = mid;
                }
            }
            choices = lagrangian_pick(groups, &costs, hi);
        }
        greedy_upgrade(groups, &costs, &mut choices, budget);
        let sol = evaluate(groups, &choices, r);
        debug_assert!(sol.bytes <= budget * (1.0 + 1e-9));
        if best.as_ref().map_or(true, |b| sol.objective < b.objective) {
            best = Some(sol);
        }
        // budget-slack repair of the incumbent under this λ's scalar cost
        if let Some(w) = warm {
            let mut wc = w.to_vec();
            greedy_upgrade(groups, &costs, &mut wc, budget);
            let sol = evaluate(groups, &wc, r);
            debug_assert!(sol.bytes <= budget * (1.0 + 1e-9));
            if best.as_ref().map_or(true, |b| sol.objective < b.objective) {
                best = Some(sol);
            }
        }
    }
    Ok(best.unwrap())
}

/// Exact exponential solver for validation (≤ ~8 groups).
pub fn solve_exact(groups: &[McKpGroup], r: f64, budget: f64) -> Option<Solution> {
    assert!(groups.len() <= 10, "exact solver is exponential");
    let mut best: Option<Solution> = None;
    let mut choices = vec![0usize; groups.len()];
    fn rec(
        groups: &[McKpGroup],
        gi: usize,
        choices: &mut Vec<usize>,
        r: f64,
        budget: f64,
        best: &mut Option<Solution>,
    ) {
        if gi == groups.len() {
            let sol = evaluate(groups, choices, r);
            if sol.bytes <= budget && best.as_ref().map_or(true, |b| sol.objective < b.objective) {
                *best = Some(sol);
            }
            return;
        }
        for i in 0..groups[gi].items.len() {
            choices[gi] = i;
            rec(groups, gi + 1, choices, r, budget, best);
        }
    }
    rec(groups, 0, &mut choices, r, budget, &mut best);
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_groups(n: usize, items: usize, rng: &mut Rng) -> Vec<McKpGroup> {
        (0..n)
            .map(|gi| McKpGroup {
                block: 0,
                expert: gi,
                linear: 0,
                items: (0..items)
                    .map(|i| {
                        // realistic structure: more bytes ⇒ less delta, and
                        // a loose delta/time anticorrelation with noise
                        let bytes = (i + 1) as f64 * 100.0;
                        Item {
                            scheme: QuantScheme::FP16,
                            delta: rng.range_f64(0.5, 1.5) / (i + 1) as f64,
                            time: rng.range_f64(0.5, 1.5) * (0.3 + 0.1 * i as f64),
                            bytes,
                        }
                    })
                    .collect(),
            })
            .collect()
    }

    #[test]
    fn respects_budget() {
        let mut rng = Rng::new(160);
        let groups = random_groups(40, 4, &mut rng);
        for budget in [4500.0, 8000.0, 16000.0] {
            let sol = solve_mckp(&groups, 0.75, budget).unwrap();
            assert!(sol.bytes <= budget + 1e-6, "bytes {} budget {budget}", sol.bytes);
        }
    }

    #[test]
    fn infeasible_budget_errors() {
        let mut rng = Rng::new(161);
        let groups = random_groups(5, 3, &mut rng);
        assert!(solve_mckp(&groups, 0.75, 100.0).is_err());
    }

    #[test]
    fn r_one_minimizes_loss_only() {
        let mut rng = Rng::new(162);
        let groups = random_groups(30, 4, &mut rng);
        let budget = 30.0 * 400.0; // everything affordable
        let sol = solve_mckp(&groups, 1.0, budget).unwrap();
        // with unlimited budget and r=1, every group takes its min-delta item
        for (g, &c) in groups.iter().zip(&sol.choices) {
            let min_d = g.items.iter().map(|i| i.delta).fold(f64::INFINITY, f64::min);
            assert!((g.items[c].delta - min_d).abs() < 1e-12);
        }
    }

    #[test]
    fn r_zero_minimizes_time_only() {
        let mut rng = Rng::new(163);
        let groups = random_groups(30, 4, &mut rng);
        let budget = 30.0 * 400.0;
        let sol = solve_mckp(&groups, 0.0, budget).unwrap();
        for (g, &c) in groups.iter().zip(&sol.choices) {
            let min_t = g.items.iter().map(|i| i.time).fold(f64::INFINITY, f64::min);
            assert!((g.items[c].time - min_t).abs() < 1e-12);
        }
    }

    #[test]
    fn near_optimal_vs_exact_small() {
        let mut rng = Rng::new(164);
        for trial in 0..10 {
            let groups = random_groups(6, 3, &mut rng);
            let budget = rng.range_f64(900.0, 1800.0);
            let exact = match solve_exact(&groups, 0.75, budget) {
                Some(s) => s,
                None => continue,
            };
            let heur = solve_mckp(&groups, 0.75, budget).unwrap();
            assert!(
                heur.objective <= exact.objective * 1.15 + 1e-12,
                "trial {trial}: heuristic {} vs exact {}",
                heur.objective,
                exact.objective
            );
        }
    }

    #[test]
    fn warm_start_never_worse_than_incumbent() {
        let mut rng = Rng::new(167);
        for trial in 0..10 {
            let groups = random_groups(20, 4, &mut rng);
            // feasible incumbent: cheapest item everywhere, then a few
            // random (still feasible after check) perturbations
            let budget = 20.0 * 250.0;
            let mut warm: Vec<usize> = groups
                .iter()
                .map(|g| {
                    let mut best = 0;
                    for (i, item) in g.items.iter().enumerate() {
                        if item.bytes < g.items[best].bytes {
                            best = i;
                        }
                    }
                    best
                })
                .collect();
            for _ in 0..5 {
                let gi = rng.below(20) as usize;
                let old = warm[gi];
                warm[gi] = rng.below(4) as usize;
                if groups.iter().zip(&warm).map(|(g, &c)| g.items[c].bytes).sum::<f64>() > budget {
                    warm[gi] = old;
                }
            }
            let incumbent = evaluate(&groups, &warm, 0.75);
            let sol = solve_mckp_warm(&groups, 0.75, budget, Some(&warm)).unwrap();
            assert!(
                sol.objective <= incumbent.objective + 1e-12,
                "trial {trial}: warm-started solve {} worse than incumbent {}",
                sol.objective,
                incumbent.objective
            );
        }
    }

    #[test]
    fn invalid_warm_start_is_ignored() {
        let mut rng = Rng::new(168);
        let groups = random_groups(10, 3, &mut rng);
        let budget = 10.0 * 200.0;
        let cold = solve_mckp(&groups, 0.75, budget).unwrap();
        // wrong length and out-of-range indices must both be ignored
        let bad_len = vec![0usize; 3];
        let s1 = solve_mckp_warm(&groups, 0.75, budget, Some(&bad_len)).unwrap();
        assert!((s1.objective - cold.objective).abs() < 1e-12);
        let bad_idx = vec![99usize; 10];
        let s2 = solve_mckp_warm(&groups, 0.75, budget, Some(&bad_idx)).unwrap();
        assert!((s2.objective - cold.objective).abs() < 1e-12);
        // infeasible incumbent (max bytes everywhere, over budget) ignored
        let fat: Vec<usize> = groups.iter().map(|g| g.items.len() - 1).collect();
        if groups.iter().zip(&fat).map(|(g, &c)| g.items[c].bytes).sum::<f64>() > budget {
            let s3 = solve_mckp_warm(&groups, 0.75, budget, Some(&fat)).unwrap();
            assert!(s3.bytes <= budget + 1e-6);
        }
    }

    #[test]
    fn tighter_budget_never_improves_objective() {
        let mut rng = Rng::new(165);
        let groups = random_groups(25, 4, &mut rng);
        let loose = solve_mckp(&groups, 0.75, 25.0 * 400.0).unwrap();
        let tight = solve_mckp(&groups, 0.75, 25.0 * 150.0).unwrap();
        assert!(tight.objective >= loose.objective - 1e-12);
    }

    #[test]
    fn smaller_r_trades_loss_for_time() {
        let mut rng = Rng::new(166);
        let groups = random_groups(50, 4, &mut rng);
        let budget = 50.0 * 400.0;
        let acc = solve_mckp(&groups, 1.0, budget).unwrap();
        let fast = solve_mckp(&groups, 0.0, budget).unwrap();
        assert!(fast.t <= acc.t + 1e-12);
        assert!(acc.l <= fast.l + 1e-12);
        let mid = solve_mckp(&groups, 0.5, budget).unwrap();
        assert!(mid.t <= acc.t + 1e-12 && mid.l <= fast.l + 1e-12);
    }
}
