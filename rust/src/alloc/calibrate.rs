//! Calibration pass: offline statistics for the allocator and GPTQ
//! (§4.2.1 "we employ a small calibration set ... expert activation
//! patterns are gathered offline").

use anyhow::Result;

use crate::moe::block::LinearKind;
use crate::moe::lm::Ffn;
use crate::moe::MoeLm;
use crate::quant::gptq::accumulate_hessian;
use crate::quant::hadamard::rotate_activations;
use crate::tensor::Matrix;

/// Per-MoE-layer calibration data.
pub struct LayerStats {
    /// Transformer layer index.
    pub layer: usize,
    /// Tokens routed to each routed expert over the calibration set
    /// (Fig. 1b right histogram).
    pub activation_counts: Vec<usize>,
    /// MoE-block inputs (concatenated over sequences, row-capped).
    pub moe_inputs: Matrix,
    /// GPTQ Hessians `Σ XᵀX` per (expert incl. shared, linear):
    /// gate/up share the expert's input Hessian; down uses the intermediate.
    pub hessians: Vec<[Matrix; 3]>,
}

/// Whole-model calibration data.
pub struct CalibrationStats {
    pub layers: Vec<LayerStats>,
    /// Sequences used (for reporting).
    pub n_sequences: usize,
}

/// Cap on stored MoE-input rows per layer (keeps sensitivity estimation
/// cheap; the paper uses 128×4096-token sequences, we keep a sample).
const MAX_INPUT_ROWS: usize = 1024;

/// Run the calibration pass. When `hadamard_signs` is given
/// (`(signs_hidden, signs_inter)` per the model's shared rotation), the
/// Hessians are accumulated in the *rotated* basis, matching the
/// rotate-then-GPTQ pipeline of §4.2.2.
pub fn calibrate(
    lm: &MoeLm,
    seqs: &[&[u32]],
    hadamard_signs: Option<(&[f32], &[f32])>,
) -> Result<CalibrationStats> {
    let cfg = &lm.cfg;
    let total_experts = cfg.n_experts + cfg.n_shared;
    let mut layers: Vec<LayerStats> = lm
        .moe_blocks()
        .iter()
        .map(|(l, _)| LayerStats {
            layer: *l,
            activation_counts: vec![0; cfg.n_experts],
            moe_inputs: Matrix::zeros(0, cfg.hidden),
            hessians: (0..total_experts)
                .map(|_| {
                    [
                        Matrix::zeros(cfg.hidden, cfg.hidden),
                        Matrix::zeros(cfg.hidden, cfg.hidden),
                        Matrix::zeros(cfg.inter, cfg.inter),
                    ]
                })
                .collect(),
        })
        .collect();

    for seq in seqs {
        let (_, caps) = lm.forward_capture(seq);
        for (li, cap) in caps.iter().enumerate() {
            let stats = &mut layers[li];
            debug_assert_eq!(stats.layer, cap.layer);
            for (e, count) in cap.routing.activation_counts().iter().enumerate() {
                stats.activation_counts[e] += count;
            }
            // stash block inputs (capped)
            if stats.moe_inputs.rows < MAX_INPUT_ROWS {
                let take = (MAX_INPUT_ROWS - stats.moe_inputs.rows).min(cap.moe_input.rows);
                let mut data = stats.moe_inputs.data.clone();
                data.extend_from_slice(&cap.moe_input.data[..take * cfg.hidden]);
                stats.moe_inputs =
                    Matrix::from_vec(stats.moe_inputs.rows + take, cfg.hidden, data);
            }
            // Hessians per expert
            let block = match &lm.layers[cap.layer].ffn {
                Ffn::Moe(b) => b,
                Ffn::Dense(_) => unreachable!("capture only fires on MoE layers"),
            };
            for e in 0..total_experts {
                let xe = if e < cfg.n_experts {
                    let tokens = cap.routing.tokens_of(e);
                    if tokens.is_empty() {
                        continue;
                    }
                    cap.moe_input.gather_rows(tokens)
                } else {
                    cap.moe_input.clone() // shared experts see all tokens
                };
                let inter = block.expert_at(e).intermediate(&xe);
                let (x_in, h_in) = match hadamard_signs {
                    Some((sh, si)) => (
                        rotate_activations(&xe, sh),
                        rotate_activations(&inter, si),
                    ),
                    None => (xe, inter),
                };
                accumulate_hessian(&mut layers[li].hessians[e][LinearKind::Gate.idx()], &x_in);
                // gate and up share inputs: copy instead of re-accumulating
                let gate_h = layers[li].hessians[e][LinearKind::Gate.idx()].clone();
                layers[li].hessians[e][LinearKind::Up.idx()] = gate_h;
                accumulate_hessian(&mut layers[li].hessians[e][LinearKind::Down.idx()], &h_in);
            }
        }
    }

    Ok(CalibrationStats { layers, n_sequences: seqs.len() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::ModelConfig;
    use crate::util::Rng;

    fn tiny() -> (MoeLm, Vec<Vec<u32>>) {
        let cfg = ModelConfig {
            name: "tiny".into(),
            vocab: 32,
            hidden: 16,
            layers: 2,
            heads: 2,
            n_experts: 4,
            n_shared: 1,
            topk: 2,
            inter: 8,
            dense_first: false,
            seq_len: 16,
        };
        let mut rng = Rng::new(140);
        let lm = MoeLm::random(&cfg, &mut rng);
        let seqs: Vec<Vec<u32>> = (0..4)
            .map(|_| (0..16).map(|_| rng.below(32) as u32).collect())
            .collect();
        (lm, seqs)
    }

    #[test]
    fn calibration_counts_and_shapes() {
        let (lm, seqs) = tiny();
        let refs: Vec<&[u32]> = seqs.iter().map(|s| s.as_slice()).collect();
        let stats = calibrate(&lm, &refs, None).unwrap();
        assert_eq!(stats.layers.len(), 2);
        for ls in &stats.layers {
            // every token activates topk experts
            assert_eq!(ls.activation_counts.iter().sum::<usize>(), 4 * 16 * 2);
            assert_eq!(ls.moe_inputs.rows, 64);
            assert_eq!(ls.hessians.len(), 5);
            // gate hessian == up hessian, shapes right
            assert_eq!(ls.hessians[0][0].rows, 16);
            assert_eq!(ls.hessians[0][2].rows, 8);
            assert_eq!(ls.hessians[1][0], ls.hessians[1][1]);
        }
    }

    #[test]
    fn shared_expert_hessian_sees_all_tokens() {
        let (lm, seqs) = tiny();
        let refs: Vec<&[u32]> = seqs.iter().map(|s| s.as_slice()).collect();
        let stats = calibrate(&lm, &refs, None).unwrap();
        let ls = &stats.layers[0];
        // shared expert (index 4) Hessian trace ≥ any routed expert's
        let trace = |m: &Matrix| (0..m.rows).map(|i| m.at(i, i) as f64).sum::<f64>();
        let shared_tr = trace(&ls.hessians[4][0]);
        for e in 0..4 {
            assert!(shared_tr >= trace(&ls.hessians[e][0]) - 1e-6);
        }
    }

    #[test]
    fn rotated_hessians_differ_but_same_trace_scale() {
        let (lm, seqs) = tiny();
        let refs: Vec<&[u32]> = seqs.iter().map(|s| s.as_slice()).collect();
        let mut rng = Rng::new(141);
        let sh = crate::quant::hadamard::random_signs(16, &mut rng);
        let si = crate::quant::hadamard::random_signs(8, &mut rng);
        let plain = calibrate(&lm, &refs, None).unwrap();
        let rot = calibrate(&lm, &refs, Some((&sh, &si))).unwrap();
        let trace = |m: &Matrix| (0..m.rows).map(|i| m.at(i, i) as f64).sum::<f64>();
        // rotation is orthogonal: total energy (trace of XᵀX) is preserved
        let t_plain = trace(&plain.layers[0].hessians[4][0]);
        let t_rot = trace(&rot.layers[0].hessians[4][0]);
        assert!((t_plain - t_rot).abs() / t_plain < 1e-3, "{t_plain} vs {t_rot}");
        // but the matrices themselves differ
        assert!(plain.layers[0].hessians[4][0].l2_distance(&rot.layers[0].hessians[4][0]) > 1e-3);
    }
}
