//! Bit packing for 2/4/8-bit integer codes.
//!
//! The artifact path stores low-bit weights physically packed (two int4
//! nibbles or four int2 crumbs per byte) exactly as the Pallas kernels
//! unpack them (`python/compile/kernels/dequant_gemm.py`); this module is
//! the rust side of that contract plus the memory-accounting ground truth.

use anyhow::{bail, Result};

/// Pack unsigned codes (`0 ≤ c < 2^bits`) into bytes, little-end first
/// (element 0 occupies the least-significant bits of byte 0).
pub fn pack(codes: &[i32], bits: u8) -> Result<Vec<u8>> {
    let per_byte = match bits {
        2 => 4,
        4 => 2,
        8 => 1,
        _ => bail!("pack: unsupported bit width {bits}"),
    };
    let mask = (1u32 << bits) - 1;
    let mut out = vec![0u8; (codes.len() + per_byte - 1) / per_byte];
    for (i, &c) in codes.iter().enumerate() {
        if c < 0 || (c as u32) > mask {
            bail!("pack: code {c} out of range for {bits} bits");
        }
        let byte = i / per_byte;
        let shift = (i % per_byte) as u32 * bits as u32;
        out[byte] |= ((c as u32 & mask) << shift) as u8;
    }
    Ok(out)
}

/// Unpack `n` codes from packed bytes.
pub fn unpack(packed: &[u8], bits: u8, n: usize) -> Result<Vec<i32>> {
    let per_byte = match bits {
        2 => 4,
        4 => 2,
        8 => 1,
        _ => bail!("unpack: unsupported bit width {bits}"),
    };
    if packed.len() * per_byte < n {
        bail!("unpack: need {n} codes, payload holds {}", packed.len() * per_byte);
    }
    let mask = (1u32 << bits) - 1;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let byte = packed[i / per_byte] as u32;
        let shift = (i % per_byte) as u32 * bits as u32;
        out.push(((byte >> shift) & mask) as i32);
    }
    Ok(out)
}

/// Offset signed symmetric codes into the unsigned packing range.
pub fn to_unsigned(codes: &[i32], bits: u8) -> Vec<i32> {
    let offset = 1i32 << (bits - 1);
    codes.iter().map(|c| c + offset).collect()
}

/// Inverse of [`to_unsigned`].
pub fn to_signed(codes: &[i32], bits: u8) -> Vec<i32> {
    let offset = 1i32 << (bits - 1);
    codes.iter().map(|c| c - offset).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = Rng::new(60);
        for bits in [2u8, 4, 8] {
            let hi = 1i32 << bits;
            let codes: Vec<i32> = (0..1000).map(|_| rng.below(hi as u64) as i32).collect();
            let packed = pack(&codes, bits).unwrap();
            assert_eq!(packed.len(), (1000 * bits as usize + 7) / 8);
            let un = unpack(&packed, bits, 1000).unwrap();
            assert_eq!(codes, un);
        }
    }

    #[test]
    fn signed_offset_roundtrip() {
        let codes = vec![-8, -1, 0, 7];
        let u = to_unsigned(&codes, 4);
        assert_eq!(u, vec![0, 7, 8, 15]);
        assert_eq!(to_signed(&u, 4), codes);
    }

    #[test]
    fn rejects_out_of_range() {
        assert!(pack(&[4], 2).is_err());
        assert!(pack(&[-1], 4).is_err());
        assert!(pack(&[0], 3).is_err());
    }

    #[test]
    fn odd_length_pads() {
        let codes = vec![3, 1, 2];
        let p = pack(&codes, 4).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(unpack(&p, 4, 3).unwrap(), codes);
    }

    #[test]
    fn nibble_layout_is_little_end_first() {
        // element 0 → low nibble, element 1 → high nibble
        let p = pack(&[0xA, 0xB], 4).unwrap();
        assert_eq!(p, vec![0xBA]);
    }
}
