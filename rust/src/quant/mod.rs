//! Quantization stack: uniform quantizers, RTN, GPTQ, randomized Hadamard
//! incoherence processing, bit packing, and the hardware-supported scheme
//! registry used by the bitwidth allocator.
//!
//! All weight quantizers operate on `[n, k]` row-major weight matrices of
//! `y = x·Wᵀ` linear layers; groups run along the `k` (input-channel) axis,
//! matching the paper's `w_gsize` notation (−1 = per-output-channel).

pub mod gptq;
pub mod hadamard;
pub mod pack;
pub mod rtn;
pub mod scheme;
pub mod uniform;

pub use gptq::gptq_quantize;
pub use hadamard::{fwht, random_signs, rotate_activations, rotate_weight};
pub use rtn::rtn_quantize;
pub use scheme::{QuantScheme, SchemeRegistry};
pub use uniform::{fake_quant_matrix, fake_quant_rows_act, GroupSpec};
