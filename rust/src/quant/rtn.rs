//! Round-to-nearest (RTN) weight quantization — the baseline quantizer used
//! in Tab. 4/5 and the fallback when no calibration data is available.

use crate::tensor::Matrix;

use super::scheme::QuantScheme;
use super::uniform::fake_quant_matrix;

/// Fake-quantize a weight matrix under `scheme` with plain RTN.
pub fn rtn_quantize(w: &Matrix, scheme: &QuantScheme) -> Matrix {
    fake_quant_matrix(w, scheme.wbits, scheme.wgroup, scheme.wsym)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn fp16_scheme_is_identity() {
        let mut rng = Rng::new(30);
        let w = Matrix::randn(4, 64, 1.0, &mut rng);
        assert_eq!(rtn_quantize(&w, &QuantScheme::FP16), w);
    }

    #[test]
    fn w8_close_w2_far() {
        let mut rng = Rng::new(31);
        let w = Matrix::randn(16, 128, 1.0, &mut rng);
        let e8 = w.l2_distance(&rtn_quantize(&w, &QuantScheme::W8A8));
        let e2 = w.l2_distance(&rtn_quantize(&w, &QuantScheme::W2A16G128));
        assert!(e8 < 0.05 * w.frob_norm());
        assert!(e2 > 4.0 * e8);
    }
}
