//! Randomized Hadamard incoherence processing (QuaRot-style, §4.2.2).
//!
//! For a linear layer `y = x·Wᵀ` we insert an orthogonal rotation
//! `Q = diag(s)·H/√k` (random signs `s`, Walsh–Hadamard `H`) along the
//! shared `k` axis: `y = (x·Q)·(W·Q)ᵀ` exactly, because `Q·Qᵀ = I`.
//! Rotated weights have incoherent (outlier-free) rows, which makes
//! low-bit uniform quantization dramatically more accurate.
//!
//! `k` must be a power of two (the paper disables online rotation when the
//! model's shapes don't allow it; our mini models use power-of-two dims).

use crate::tensor::Matrix;
use crate::util::Rng;

/// In-place fast Walsh–Hadamard transform (unnormalized butterflies).
/// `xs.len()` must be a power of two.
pub fn fwht(xs: &mut [f32]) {
    let n = xs.len();
    assert!(n.is_power_of_two(), "FWHT length {n} not a power of two");
    let mut h = 1;
    while h < n {
        for i in (0..n).step_by(h * 2) {
            for j in i..i + h {
                let x = xs[j];
                let y = xs[j + h];
                xs[j] = x + y;
                xs[j + h] = x - y;
            }
        }
        h *= 2;
    }
}

/// Draw the random ±1 diagonal for a k-dim rotation.
pub fn random_signs(k: usize, rng: &mut Rng) -> Vec<f32> {
    (0..k).map(|_| rng.sign()).collect()
}

/// Apply `M ← M·Q` with `Q = diag(s)·H/√k`, rows independently:
/// each row is sign-flipped, FWHT'd and scaled by `1/√k`.
pub fn rotate_rows(m: &mut Matrix, signs: &[f32]) {
    assert_eq!(m.cols, signs.len());
    let inv_sqrt = 1.0 / (m.cols as f32).sqrt();
    for r in 0..m.rows {
        let row = m.row_mut(r);
        for (v, s) in row.iter_mut().zip(signs) {
            *v *= s;
        }
        fwht(row);
        for v in row.iter_mut() {
            *v *= inv_sqrt;
        }
    }
}

/// Apply the inverse rotation `M ← M·Qᵀ` (`Qᵀ = H·diag(s)/√k`).
pub fn rotate_rows_inverse(m: &mut Matrix, signs: &[f32]) {
    assert_eq!(m.cols, signs.len());
    let inv_sqrt = 1.0 / (m.cols as f32).sqrt();
    for r in 0..m.rows {
        let row = m.row_mut(r);
        fwht(row);
        for (v, s) in row.iter_mut().zip(signs) {
            *v *= s * inv_sqrt;
        }
    }
}

/// Rotate a weight matrix (`[n, k]`, k = input channels): `W ← W·Q`.
pub fn rotate_weight(w: &Matrix, signs: &[f32]) -> Matrix {
    let mut out = w.clone();
    rotate_rows(&mut out, signs);
    out
}

/// Rotate activations (`[tokens, k]`): `X ← X·Q`.
pub fn rotate_activations(x: &Matrix, signs: &[f32]) -> Matrix {
    let mut out = x.clone();
    rotate_rows(&mut out, signs);
    out
}

/// Can a k-dim axis be rotated (power-of-two constraint)?
pub fn hadamard_compatible(k: usize) -> bool {
    k.is_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matrix::matmul_nt;

    #[test]
    fn fwht_involution_up_to_n() {
        let mut rng = Rng::new(50);
        let n = 64;
        let orig: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let mut xs = orig.clone();
        fwht(&mut xs);
        fwht(&mut xs);
        for (a, b) in xs.iter().zip(&orig) {
            assert!((a / n as f32 - b).abs() < 1e-4);
        }
    }

    #[test]
    fn rotation_preserves_gemm_exactly() {
        let mut rng = Rng::new(51);
        let (m, k, n) = (5, 128, 7);
        let x = Matrix::randn(m, k, 1.0, &mut rng);
        let w = Matrix::randn(n, k, 1.0, &mut rng);
        let signs = random_signs(k, &mut rng);
        let y = matmul_nt(&x, &w);
        let y_rot = matmul_nt(&rotate_activations(&x, &signs), &rotate_weight(&w, &signs));
        for (a, b) in y.data.iter().zip(&y_rot.data) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn rotation_inverse_roundtrip() {
        let mut rng = Rng::new(52);
        let x = Matrix::randn(3, 32, 1.0, &mut rng);
        let signs = random_signs(32, &mut rng);
        let mut y = x.clone();
        rotate_rows(&mut y, &signs);
        rotate_rows_inverse(&mut y, &signs);
        for (a, b) in y.data.iter().zip(&x.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn rotation_preserves_norm() {
        let mut rng = Rng::new(53);
        let x = Matrix::randn(4, 64, 1.0, &mut rng);
        let signs = random_signs(64, &mut rng);
        let y = rotate_activations(&x, &signs);
        assert!((x.frob_norm() - y.frob_norm()).abs() < 1e-3);
    }

    #[test]
    fn rotation_suppresses_outliers() {
        // dense Gaussian rows with a few massive outlier channels: the
        // outliers blow up the per-channel scale and drown the dense mass.
        let mut rng = Rng::new(54);
        let mut w = Matrix::randn(8, 256, 1.0, &mut rng);
        for r in 0..8 {
            w.row_mut(r)[17] = 100.0;
            w.row_mut(r)[101] = -80.0;
        }
        let signs = random_signs(256, &mut rng);
        let r = rotate_weight(&w, &signs);
        assert!(r.max_abs() < w.max_abs(), "rotation must spread outliers");
        // quantization error at 4 bits improves correspondingly
        let e_raw = w.l2_distance(&crate::quant::uniform::fake_quant_matrix(&w, 4, -1, true));
        let e_rot = r.l2_distance(&crate::quant::uniform::fake_quant_matrix(&r, 4, -1, true));
        assert!(e_rot < e_raw, "rot {e_rot} !< raw {e_raw}");
    }
}
