//! Uniform (affine) quantization primitives: the `Q(·)` of §2.1.
//!
//! Symmetric: `q = clamp(round(x/Δ), −2^{b−1}, 2^{b−1}−1)`, `x̂ = q·Δ`.
//! Asymmetric (min-max): `q = round((x − x_min)/Δ)`, `x̂ = q·Δ + x_min`.

use crate::tensor::Matrix;

use super::scheme::GroupSize;

/// Resolved grouping along a length-`k` axis.
#[derive(Clone, Copy, Debug)]
pub struct GroupSpec {
    pub k: usize,
    pub group: usize,
}

impl GroupSpec {
    /// `group_size` uses the paper's convention: −1 ⇒ a single group of the
    /// whole channel/token. Groups wider than the axis clamp to per-channel
    /// (a g128 scheme applied to a k=64 projection degenerates gracefully).
    pub fn new(k: usize, group_size: GroupSize) -> GroupSpec {
        let group = if group_size <= 0 {
            k
        } else {
            (group_size as usize).min(k)
        };
        assert!(group > 0 && k % group == 0, "k={k} not divisible by group={group}");
        GroupSpec { k, group }
    }

    pub fn num_groups(&self) -> usize {
        self.k / self.group
    }
}

/// Quantization parameters of one group.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QParams {
    pub scale: f32,
    /// `zero` is the dequant offset: `x̂ = q·scale + zero` (0 for symmetric).
    pub zero: f32,
    pub qmin: i32,
    pub qmax: i32,
}

/// Compute min-max parameters of a group.
pub fn qparams(xs: &[f32], bits: u8, sym: bool) -> QParams {
    debug_assert!(bits >= 2 && bits < 16);
    if sym {
        let qmax = (1i32 << (bits - 1)) - 1;
        let qmin = -(1i32 << (bits - 1));
        let amax = xs.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        let scale = if amax > 0.0 { amax / qmax as f32 } else { 1.0 };
        QParams { scale, zero: 0.0, qmin, qmax }
    } else {
        let qmax = (1i32 << bits) - 1;
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &x in xs {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        // quantization range must include 0 so that padding stays exact
        lo = lo.min(0.0);
        hi = hi.max(0.0);
        let scale = if hi > lo { (hi - lo) / qmax as f32 } else { 1.0 };
        QParams { scale, zero: lo, qmin: 0, qmax }
    }
}

/// Quantize one value under `p`.
#[inline]
pub fn quantize_one(x: f32, p: &QParams) -> i32 {
    let q = ((x - p.zero) / p.scale).round() as i32;
    q.clamp(p.qmin, p.qmax)
}

/// Dequantize one code under `p`.
#[inline]
pub fn dequantize_one(q: i32, p: &QParams) -> f32 {
    q as f32 * p.scale + p.zero
}

/// Fake-quantize (quantize → dequantize) a slice in place under `p`.
pub fn fake_quant_slice(xs: &mut [f32], p: &QParams) {
    for x in xs.iter_mut() {
        *x = dequantize_one(quantize_one(*x, p), p);
    }
}

/// Fake-quantize a `[n, k]` weight matrix with groups along `k`.
/// `bits = 16` is a pass-through (fp16 kept as f32 here; fp16 rounding error
/// is negligible at the model scales we evaluate and is modeled as exact).
pub fn fake_quant_matrix(w: &Matrix, bits: u8, group_size: GroupSize, sym: bool) -> Matrix {
    if bits >= 16 {
        return w.clone();
    }
    let spec = GroupSpec::new(w.cols, group_size);
    let mut out = w.clone();
    for r in 0..w.rows {
        let row = out.row_mut(r);
        for g in 0..spec.num_groups() {
            let seg = &mut row[g * spec.group..(g + 1) * spec.group];
            let p = qparams(seg, bits, sym);
            fake_quant_slice(seg, &p);
        }
    }
    out
}

/// Dynamic per-token (row) activation fake-quant with groups along `k` —
/// what the runtime does before a weight-activation GEMM.
pub fn fake_quant_rows_act(x: &Matrix, bits: u8, group_size: GroupSize) -> Matrix {
    if bits >= 16 {
        return x.clone();
    }
    fake_quant_matrix(x, bits, group_size, true)
}

/// Full (non-fake) quantization of a weight matrix: integer codes plus
/// per-group parameters. Used for packing/artifact export and tests.
pub struct QuantizedWeight {
    pub codes: Vec<i32>, // [n, k] row-major
    pub params: Vec<QParams>, // [n, num_groups] row-major
    pub group: usize,
}

pub fn quantize_matrix(w: &Matrix, bits: u8, group_size: GroupSize, sym: bool) -> QuantizedWeight {
    assert!(bits < 16);
    let spec = GroupSpec::new(w.cols, group_size);
    let mut codes = vec![0i32; w.rows * w.cols];
    let mut params = Vec::with_capacity(w.rows * spec.num_groups());
    for r in 0..w.rows {
        let row = w.row(r);
        for g in 0..spec.num_groups() {
            let seg = &row[g * spec.group..(g + 1) * spec.group];
            let p = qparams(seg, bits, sym);
            for (i, &x) in seg.iter().enumerate() {
                codes[r * w.cols + g * spec.group + i] = quantize_one(x, &p);
            }
            params.push(p);
        }
    }
    QuantizedWeight { codes, params, group: spec.group }
}

/// Reconstruct the fake-quant matrix from a [`QuantizedWeight`].
pub fn dequantize_matrix(q: &QuantizedWeight, rows: usize, cols: usize) -> Matrix {
    let groups_per_row = cols / q.group;
    let mut out = Matrix::zeros(rows, cols);
    for r in 0..rows {
        for c in 0..cols {
            let p = &q.params[r * groups_per_row + c / q.group];
            out.data[r * cols + c] = dequantize_one(q.codes[r * cols + c], p);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn sym_qparams_cover_range() {
        let xs = [-3.0f32, 1.0, 2.5];
        let p = qparams(&xs, 4, true);
        assert_eq!(p.zero, 0.0);
        assert_eq!(p.qmin, -8);
        assert_eq!(p.qmax, 7);
        // max-abs element reconstructs within half a step
        let q = quantize_one(-3.0, &p);
        assert!((dequantize_one(q, &p) + 3.0).abs() <= p.scale * 0.51);
    }

    #[test]
    fn asym_includes_zero() {
        let xs = [2.0f32, 3.0, 4.0];
        let p = qparams(&xs, 4, false);
        // range forced to include 0 ⇒ zero offset is 0 here
        assert_eq!(p.zero, 0.0);
        let q0 = quantize_one(0.0, &p);
        assert_eq!(dequantize_one(q0, &p), 0.0);
    }

    #[test]
    fn error_bounded_by_half_step() {
        let mut rng = Rng::new(21);
        for bits in [2u8, 3, 4, 8] {
            let xs: Vec<f32> = (0..256).map(|_| rng.normal_f32() * 3.0).collect();
            let p = qparams(&xs, bits, true);
            for &x in &xs {
                let xq = dequantize_one(quantize_one(x, &p), &p);
                assert!(
                    (x - xq).abs() <= p.scale * 0.5 + 1e-6,
                    "bits={bits} x={x} xq={xq} scale={}",
                    p.scale
                );
            }
        }
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(22);
        let w = Matrix::randn(16, 128, 1.0, &mut rng);
        let mut last = f64::INFINITY;
        for bits in [2u8, 3, 4, 8] {
            let wq = fake_quant_matrix(&w, bits, -1, true);
            let err = w.l2_distance(&wq);
            assert!(err < last, "bits={bits}: {err} !< {last}");
            last = err;
        }
    }

    #[test]
    fn grouping_reduces_error() {
        let mut rng = Rng::new(23);
        // heavy-tailed row: one outlier per row makes per-channel scales bad
        let mut w = Matrix::randn(8, 256, 1.0, &mut rng);
        for r in 0..8 {
            w.row_mut(r)[0] *= 50.0;
        }
        let per_channel = w.l2_distance(&fake_quant_matrix(&w, 4, -1, true));
        let grouped = w.l2_distance(&fake_quant_matrix(&w, 4, 128, true));
        assert!(grouped < per_channel, "{grouped} !< {per_channel}");
    }

    #[test]
    fn bits16_identity() {
        let mut rng = Rng::new(24);
        let w = Matrix::randn(4, 32, 1.0, &mut rng);
        assert_eq!(fake_quant_matrix(&w, 16, -1, true), w);
    }

    #[test]
    fn quantize_dequantize_matches_fake() {
        let mut rng = Rng::new(25);
        let w = Matrix::randn(6, 64, 2.0, &mut rng);
        for &(bits, group, sym) in &[(4u8, -1i32, true), (3, 32, false), (8, 16, true)] {
            let q = quantize_matrix(&w, bits, group, sym);
            let deq = dequantize_matrix(&q, w.rows, w.cols);
            let fake = fake_quant_matrix(&w, bits, group, sym);
            for (a, b) in deq.data.iter().zip(&fake.data) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn codes_within_range() {
        let mut rng = Rng::new(26);
        let w = Matrix::randn(3, 32, 5.0, &mut rng);
        let q = quantize_matrix(&w, 2, -1, false);
        assert!(q.codes.iter().all(|&c| (0..=3).contains(&c)));
        let qs = quantize_matrix(&w, 2, -1, true);
        assert!(qs.codes.iter().all(|&c| (-2..=1).contains(&c)));
    }
}
