//! GPTQ post-training weight quantization (Frantar et al., 2022).
//!
//! Quantizes a `[n, k]` weight matrix column-by-column, propagating the
//! rounding error of each column into the not-yet-quantized columns through
//! the inverse-Hessian Cholesky factor. The Hessian is `H = Σ XᵀX` over the
//! calibration inputs (the `2·` factor cancels in the update).
//!
//! This is the weight quantizer MxMoE applies after Hadamard incoherence
//! processing (§4.2.2 "perform GPTQ-based quantization").

use anyhow::Result;

use crate::linalg::gptq_hinv_cholesky;
use crate::tensor::Matrix;

use super::scheme::QuantScheme;
use super::uniform::{fake_quant_slice, qparams, QParams};

/// Lazy-update block width (columns), as in the reference implementation.
const BLOCK: usize = 128;

/// GPTQ-quantize `w` (`[n, k]`) under `scheme` given the calibration Hessian
/// `h` (`[k, k]`, `Σ XᵀX`). Returns the fake-quantized weight.
pub fn gptq_quantize(w: &Matrix, h: &Matrix, scheme: &QuantScheme, damp: f32) -> Result<Matrix> {
    if scheme.is_fp16() {
        return Ok(w.clone());
    }
    let (n, k) = (w.rows, w.cols);
    assert_eq!(h.rows, k);
    assert_eq!(h.cols, k);
    // groups wider than the axis clamp to per-channel (GroupSpec semantics)
    let group = if scheme.wgroup <= 0 { k } else { (scheme.wgroup as usize).min(k) };
    assert!(k % group == 0, "k={k} % group={group} != 0");

    let u = gptq_hinv_cholesky(h, damp)?; // upper triangular [k, k]
    let mut work = w.clone(); // error-compensated weights
    let mut q = w.clone(); // output fake-quant values
    let mut params: Vec<QParams> = Vec::new(); // per-row params of current group

    for b0 in (0..k).step_by(BLOCK) {
        let b1 = (b0 + BLOCK).min(k);
        let bw = b1 - b0;
        let mut err = Matrix::zeros(n, bw);
        for j in b0..b1 {
            // (re)compute group parameters at each group boundary from the
            // *error-compensated* weights, like the reference implementation
            if j % group == 0 {
                params.clear();
                let g1 = j + group;
                for r in 0..n {
                    let seg = &work.row(r)[j..g1];
                    params.push(qparams(seg, scheme.wbits, scheme.wsym));
                }
            }
            let d = u.at(j, j);
            debug_assert!(d > 0.0, "non-positive Cholesky pivot");
            for r in 0..n {
                let wv = work.at(r, j);
                let mut xq = [wv];
                fake_quant_slice(&mut xq, &params[r]);
                *q.at_mut(r, j) = xq[0];
                let e = (wv - xq[0]) / d;
                *err.at_mut(r, j - b0) = e;
                // in-block error propagation
                let urow = u.row(j);
                let wrow = work.row_mut(r);
                for c in j + 1..b1 {
                    wrow[c] -= e * urow[c];
                }
            }
        }
        // block-global propagation into the remaining columns:
        // work[:, b1..] -= err · U[b0..b1, b1..]
        if b1 < k {
            for r in 0..n {
                for (jj, j) in (b0..b1).enumerate() {
                    let e = err.at(r, jj);
                    if e == 0.0 {
                        continue;
                    }
                    let urow = u.row(j);
                    let wrow = work.row_mut(r);
                    for c in b1..k {
                        wrow[c] -= e * urow[c];
                    }
                }
            }
        }
    }
    Ok(q)
}

/// Accumulate the GPTQ Hessian `H += XᵀX` from a batch of layer inputs
/// (`x`: `[tokens, k]`).
pub fn accumulate_hessian(h: &mut Matrix, x: &Matrix) {
    assert_eq!(h.rows, x.cols);
    assert_eq!(h.cols, x.cols);
    let k = x.cols;
    for t in 0..x.rows {
        let row = x.row(t);
        for i in 0..k {
            let xi = row[i];
            if xi == 0.0 {
                continue;
            }
            let hrow = h.row_mut(i);
            for j in 0..k {
                hrow[j] += xi * row[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::rtn_quantize;
    use crate::tensor::matrix::matmul_nt;
    use crate::util::Rng;

    /// Calibration inputs with correlated channels — the regime where GPTQ's
    /// error compensation beats RTN.
    fn correlated_inputs(tokens: usize, k: usize, rng: &mut Rng) -> Matrix {
        let base = Matrix::randn(tokens, k, 1.0, rng);
        let mut x = base.clone();
        for t in 0..tokens {
            for c in 1..k {
                // mix neighbouring channels to induce off-diagonal Hessian
                x.data[t * k + c] = 0.6 * base.data[t * k + c] + 0.4 * base.data[t * k + c - 1];
            }
        }
        x
    }

    #[test]
    fn gptq_beats_rtn_on_output_error() {
        let mut rng = Rng::new(40);
        let (n, k, tokens) = (24, 128, 256);
        let w = Matrix::randn(n, k, 1.0, &mut rng);
        let x = correlated_inputs(tokens, k, &mut rng);
        let mut h = Matrix::zeros(k, k);
        accumulate_hessian(&mut h, &x);

        let scheme = QuantScheme::W3A16G128;
        let q_gptq = gptq_quantize(&w, &h, &scheme, 0.01).unwrap();
        let q_rtn = rtn_quantize(&w, &scheme);

        let y = matmul_nt(&x, &w);
        let e_gptq = y.l2_distance(&matmul_nt(&x, &q_gptq));
        let e_rtn = y.l2_distance(&matmul_nt(&x, &q_rtn));
        assert!(
            e_gptq < e_rtn,
            "gptq {e_gptq} !< rtn {e_rtn} — error compensation broken"
        );
    }

    #[test]
    fn gptq_fp16_identity() {
        let mut rng = Rng::new(41);
        let w = Matrix::randn(4, 32, 1.0, &mut rng);
        let h = {
            let x = Matrix::randn(64, 32, 1.0, &mut rng);
            let mut h = Matrix::zeros(32, 32);
            accumulate_hessian(&mut h, &x);
            h
        };
        let q = gptq_quantize(&w, &h, &QuantScheme::FP16, 0.01).unwrap();
        assert_eq!(q, w);
    }

    #[test]
    fn gptq_output_in_codebook() {
        // every produced value must be representable under the group params
        // of *some* 4-bit codebook: verify error vs fake-quant of itself is 0
        let mut rng = Rng::new(42);
        let (n, k) = (8, 64);
        let w = Matrix::randn(n, k, 1.0, &mut rng);
        let x = correlated_inputs(128, k, &mut rng);
        let mut h = Matrix::zeros(k, k);
        accumulate_hessian(&mut h, &x);
        let scheme = QuantScheme::new(4, 16, 32, -1, false);
        let q = gptq_quantize(&w, &h, &scheme, 0.01).unwrap();
        // each group segment must contain at most 2^4 distinct values
        for r in 0..n {
            for g in 0..(k / 32) {
                let seg = &q.row(r)[g * 32..(g + 1) * 32];
                let mut vals: Vec<f32> = seg.to_vec();
                vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
                vals.dedup_by(|a, b| (*a - *b).abs() < 1e-7);
                assert!(vals.len() <= 16, "row {r} group {g}: {} distinct", vals.len());
            }
        }
    }

    #[test]
    fn hessian_is_symmetric_psd_diag() {
        let mut rng = Rng::new(43);
        let x = Matrix::randn(50, 16, 1.0, &mut rng);
        let mut h = Matrix::zeros(16, 16);
        accumulate_hessian(&mut h, &x);
        for i in 0..16 {
            assert!(h.at(i, i) > 0.0);
            for j in 0..16 {
                assert!((h.at(i, j) - h.at(j, i)).abs() < 1e-3);
            }
        }
    }
}
