//! Quantization scheme descriptors and the hardware-supported registry.
//!
//! A scheme is the paper's `wXaY_gZ_{sym,asym}` notation: weight bits,
//! activation bits, group sizes (−1 = per-channel/per-token) and symmetry.
//! The registry lists the schemes a target GPU can execute efficiently
//! (§4.2.1: "Let S denote the set of hardware-supported quantization
//! schemes"), together with storage-overhead accounting used for the
//! memory-budget constraint and the "average bits" reported in Tab. 1.

use std::fmt;

/// Group size along the quantized axis. −1 ⇒ one group per channel/token.
pub type GroupSize = i32;

/// One quantization scheme (weights + activations).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QuantScheme {
    /// Weight bits (16 = keep fp16).
    pub wbits: u8,
    /// Activation bits (16 = keep fp16).
    pub abits: u8,
    /// Weight group size along k (−1 = per output channel row).
    pub wgroup: GroupSize,
    /// Activation group size along k (−1 = per token row).
    pub agroup: GroupSize,
    /// Symmetric weight quantization (no zero point).
    pub wsym: bool,
    /// Symmetric activation quantization.
    pub asym_act: bool,
}

impl QuantScheme {
    pub const fn new(wbits: u8, abits: u8, wgroup: GroupSize, agroup: GroupSize, wsym: bool) -> QuantScheme {
        QuantScheme { wbits, abits, wgroup, agroup, wsym, asym_act: false }
    }

    /// Full precision pass-through.
    pub const FP16: QuantScheme = QuantScheme::new(16, 16, -1, -1, true);
    /// Weight-only 4-bit, per-channel asymmetric (Marlin-style W4A16).
    pub const W4A16: QuantScheme = QuantScheme { wbits: 4, abits: 16, wgroup: -1, agroup: -1, wsym: false, asym_act: false };
    /// Weight-only 4-bit, group-128 asymmetric (GPTQ default, 4.25 avg bits).
    pub const W4A16G128: QuantScheme = QuantScheme { wbits: 4, abits: 16, wgroup: 128, agroup: -1, wsym: false, asym_act: false };
    /// Weight-only 3-bit, group-128 asymmetric (3.25 avg bits).
    pub const W3A16G128: QuantScheme = QuantScheme { wbits: 3, abits: 16, wgroup: 128, agroup: -1, wsym: false, asym_act: false };
    /// Weight-only 2-bit, group-128 asymmetric (2.25 avg bits).
    pub const W2A16G128: QuantScheme = QuantScheme { wbits: 2, abits: 16, wgroup: 128, agroup: -1, wsym: false, asym_act: false };
    /// Weight-only 2-bit per-channel.
    pub const W2A16: QuantScheme = QuantScheme { wbits: 2, abits: 16, wgroup: -1, agroup: -1, wsym: false, asym_act: false };
    /// 8-bit weight-activation, per-channel/token symmetric (SmoothQuant-style).
    pub const W8A8: QuantScheme = QuantScheme::new(8, 8, -1, -1, true);
    /// 4-bit weight-activation, per-channel/token symmetric (QuaRot-style).
    pub const W4A4: QuantScheme = QuantScheme::new(4, 4, -1, -1, true);
    /// 4-bit weight-activation with group-128 scales (Atom-style).
    pub const W4A4G128: QuantScheme = QuantScheme::new(4, 4, 128, 128, true);
    /// Intermediate WA points used by Tab. 4/5 sweeps.
    pub const W5A5: QuantScheme = QuantScheme::new(5, 5, -1, -1, true);
    pub const W6A6: QuantScheme = QuantScheme::new(6, 6, -1, -1, true);
    pub const W7A7: QuantScheme = QuantScheme::new(7, 7, -1, -1, true);
    pub const W8A16: QuantScheme = QuantScheme { wbits: 8, abits: 16, wgroup: -1, agroup: -1, wsym: false, asym_act: false };

    /// Canonical name, e.g. `w4a4_g128_sym`.
    pub fn name(&self) -> String {
        format!(
            "w{}a{}_g{}_{}",
            self.wbits,
            self.abits,
            self.wgroup,
            if self.wsym { "sym" } else { "asym" }
        )
    }

    /// Is this a weight-only scheme (activations stay fp16)?
    pub fn weight_only(&self) -> bool {
        self.abits == 16
    }

    pub fn is_fp16(&self) -> bool {
        self.wbits == 16
    }

    /// Average stored bits per weight element including scale/zero-point
    /// overhead (fp16 scale + fp16 zero per group), the paper's "#Bits"
    /// accounting: g128 asym ⇒ +0.25 bits; per-channel amortizes over `k`.
    pub fn avg_weight_bits(&self, k: usize) -> f64 {
        if self.is_fp16() {
            return 16.0;
        }
        let group = if self.wgroup <= 0 { k } else { (self.wgroup as usize).min(k) } as f64;
        let meta_bits = if self.wsym { 16.0 } else { 32.0 }; // scale (+ zero)
        self.wbits as f64 + meta_bits / group
    }

    /// Bytes to store a quantized `[n, k]` weight (packed payload + scales).
    pub fn weight_bytes(&self, n: usize, k: usize) -> usize {
        ((self.avg_weight_bits(k) * (n * k) as f64) / 8.0).ceil() as usize
    }

    /// Average activation bits (for reporting; activations are quantized
    /// dynamically and never stored).
    pub fn avg_act_bits(&self, k: usize) -> f64 {
        if self.abits == 16 {
            return 16.0;
        }
        let group = if self.agroup <= 0 { k } else { (self.agroup as usize).min(k) } as f64;
        self.abits as f64 + 16.0 / group
    }
}

impl fmt::Display for QuantScheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// The set `S` of schemes the target hardware supports, with helper
/// sub-registries for the experiment configurations in the paper.
#[derive(Clone, Debug)]
pub struct SchemeRegistry {
    pub schemes: Vec<QuantScheme>,
}

impl SchemeRegistry {
    /// RTX-4090-like registry used in the paper's main experiments
    /// (int2/4/8 tensor-core paths + fp16).
    pub fn rtx4090() -> SchemeRegistry {
        SchemeRegistry {
            schemes: vec![
                QuantScheme::FP16,
                QuantScheme::W2A16G128,
                QuantScheme::W3A16G128,
                QuantScheme::W4A16,
                QuantScheme::W4A16G128,
                QuantScheme::W8A16,
                QuantScheme::W8A8,
                QuantScheme::W4A4,
                QuantScheme::W4A4G128,
            ],
        }
    }

    /// Weight-only candidates for the Tab. 1 GPTQ-comparison rows
    /// (target average bits 2.25 / 3.25).
    pub fn weight_only() -> SchemeRegistry {
        SchemeRegistry {
            schemes: vec![
                QuantScheme::W2A16G128,
                QuantScheme::W3A16G128,
                QuantScheme::W4A16G128,
                QuantScheme::W4A16,
                QuantScheme::W8A16,
            ],
        }
    }

    /// Weight-activation candidates for the 5-bit rows (mix of W4A4 variants
    /// and W8A8, as in Tab. 7).
    pub fn weight_activation() -> SchemeRegistry {
        SchemeRegistry {
            schemes: vec![QuantScheme::W4A4, QuantScheme::W4A4G128, QuantScheme::W8A8],
        }
    }

    pub fn len(&self) -> usize {
        self.schemes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.schemes.is_empty()
    }

    pub fn by_name(&self, name: &str) -> Option<QuantScheme> {
        self.schemes.iter().copied().find(|s| s.name() == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gptq_bit_accounting_matches_paper() {
        // paper: 3-bit g128 asym with 16-bit scale+zero = 3.25 avg bits
        assert!((QuantScheme::W3A16G128.avg_weight_bits(2048) - 3.25).abs() < 1e-9);
        assert!((QuantScheme::W2A16G128.avg_weight_bits(2048) - 2.25).abs() < 1e-9);
        assert!((QuantScheme::W4A16G128.avg_weight_bits(2048) - 4.25).abs() < 1e-9);
    }

    #[test]
    fn per_channel_overhead_amortizes() {
        let b = QuantScheme::W4A4.avg_weight_bits(2048);
        assert!(b > 4.0 && b < 4.01, "{b}");
    }

    #[test]
    fn names_roundtrip_registry() {
        let reg = SchemeRegistry::rtx4090();
        for s in &reg.schemes {
            assert_eq!(reg.by_name(&s.name()), Some(*s));
        }
        assert_eq!(reg.by_name("w9a9_g-1_sym"), None);
    }

    #[test]
    fn weight_bytes_scale_with_bits() {
        let n = 128;
        let k = 256;
        let b4 = QuantScheme::W4A4.weight_bytes(n, k);
        let b8 = QuantScheme::W8A8.weight_bytes(n, k);
        assert!(b8 > b4);
        assert!(QuantScheme::FP16.weight_bytes(n, k) == n * k * 2);
    }
}
