//! MxMoE: mixed-precision quantization for MoE with accuracy & performance
//! co-design — full-system reproduction (rust L3 + JAX L2 + Pallas L1).
pub mod alloc;
pub mod costmodel;
pub mod data;
pub mod eval;
pub mod harness;
pub mod linalg;
pub mod kernelgen;
pub mod moe;
pub mod obs;
pub mod sched;
pub mod sim;
pub mod coordinator;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod ser;
pub mod tensor;
pub mod util;

pub fn version() -> &'static str { env!("CARGO_PKG_VERSION") }
