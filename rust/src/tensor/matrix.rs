//! Row-major dense f32 matrix with a blocked, multithreaded matmul.

use crate::util::threadpool::parallel_for;
use crate::util::Rng;

/// Row-major `rows × cols` f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(rows * cols, data.len(), "shape/payload mismatch");
        Matrix { rows, cols, data }
    }

    /// N(0, std²) initialization.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Matrix {
        let data = (0..rows * cols).map(|_| rng.normal_f32() * std).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Select a subset of rows (token gathering for expert dispatch).
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// `self += other * scale` (weighted expert-output accumulation).
    pub fn add_scaled(&mut self, other: &Matrix, scale: f32) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b * scale;
        }
    }

    /// Scatter-add rows of `src` into `self` at `idx`, scaled per row.
    pub fn scatter_add_rows(&mut self, idx: &[usize], src: &Matrix, scales: &[f32]) {
        assert_eq!(idx.len(), src.rows);
        assert_eq!(idx.len(), scales.len());
        assert_eq!(self.cols, src.cols);
        for (i, (&r, &s)) in idx.iter().zip(scales).enumerate() {
            let dst = self.row_mut(r);
            for (d, v) in dst.iter_mut().zip(src.row(i)) {
                *d += v * s;
            }
        }
    }

    /// Frobenius norm of `self - other` — the paper's Δ metric (Eq. 6).
    pub fn l2_distance(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }
}

/// `C = A · Bᵀ` where `b_t` is stored as `[n, k]` (i.e. already transposed —
/// the natural layout for `y = x · Wᵀ` linear layers with row-major weights).
///
/// Cache strategy: parallel over row blocks of A; inner loops walk
/// contiguous k-panels of both operands; the 8-lane accumulator `dot` is
/// the fastest variant on this target (§Perf tried 4×2 register blocking —
/// both variants regressed; see EXPERIMENTS.md §Perf iteration log).
pub fn matmul_nt(a: &Matrix, b_t: &Matrix) -> Matrix {
    assert_eq!(a.cols, b_t.cols, "inner dims: a [m,{}] vs b_t [n,{}]", a.cols, b_t.cols);
    let (m, k, n) = (a.rows, a.cols, b_t.rows);
    let mut out = Matrix::zeros(m, n);
    // SAFETY-free parallelism: each task owns a disjoint row range of `out`.
    let out_ptr = SendPtr(out.data.as_mut_ptr());
    const MB: usize = 16; // rows of A per task
    let tasks = (m + MB - 1) / MB;
    parallel_for(tasks, |t| {
        let r0 = t * MB;
        let r1 = (r0 + MB).min(m);
        let out_ptr = &out_ptr;
        for r in r0..r1 {
            let arow = a.row(r);
            let orow = unsafe {
                std::slice::from_raw_parts_mut(out_ptr.0.add(r * n), n)
            };
            for c in 0..n {
                let brow = b_t.row(c);
                orow[c] = dot(arow, brow);
            }
        }
    });
    let _ = k;
    out
}

/// `C = A · B` with `b` stored `[k, n]`. Implemented as accumulation over
/// k-panels (ikj order) so B rows stream contiguously.
pub fn matmul_nn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "inner dims");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut out = Matrix::zeros(m, n);
    let out_ptr = SendPtr(out.data.as_mut_ptr());
    const MB: usize = 16;
    let tasks = (m + MB - 1) / MB;
    parallel_for(tasks, |t| {
        let r0 = t * MB;
        let r1 = (r0 + MB).min(m);
        let out_ptr = &out_ptr;
        for r in r0..r1 {
            let orow = unsafe {
                std::slice::from_raw_parts_mut(out_ptr.0.add(r * n), n)
            };
            let arow = a.row(r);
            for kk in 0..k {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = b.row(kk);
                for c in 0..n {
                    orow[c] += av * brow[c];
                }
            }
        }
    });
    out
}

/// Unrolled dot product; the compiler vectorizes the 8-wide accumulators.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 8;
    let mut acc = [0.0f32; 8];
    for i in 0..chunks {
        let ai = &a[i * 8..i * 8 + 8];
        let bi = &b[i * 8..i * 8 + 8];
        for j in 0..8 {
            acc[j] += ai[j] * bi[j];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Raw pointer wrapper so disjoint row ranges can be written from worker
/// threads. Each `parallel_for` task touches rows `[r0, r1)` exclusively.
struct SendPtr(*mut f32);
unsafe impl Sync for SendPtr {}
unsafe impl Send for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_nt(a: &Matrix, bt: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows, bt.rows);
        for r in 0..a.rows {
            for c in 0..bt.rows {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(r, k) * bt.at(c, k);
                }
                *out.at_mut(r, c) = s;
            }
        }
        out
    }

    #[test]
    fn matmul_nt_matches_naive() {
        let mut rng = Rng::new(3);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 128, 40)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let bt = Matrix::randn(n, k, 1.0, &mut rng);
            let c = matmul_nt(&a, &bt);
            let c0 = naive_nt(&a, &bt);
            for (x, y) in c.data.iter().zip(&c0.data) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn matmul_nn_matches_nt_of_transpose() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(13, 21, 1.0, &mut rng);
        let b = Matrix::randn(21, 17, 1.0, &mut rng);
        let via_nn = matmul_nn(&a, &b);
        let via_nt = matmul_nt(&a, &b.transpose());
        for (x, y) in via_nn.data.iter().zip(&via_nt.data) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(37, 53, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut rng = Rng::new(6);
        let x = Matrix::randn(10, 4, 1.0, &mut rng);
        let idx = vec![2usize, 7, 5];
        let g = x.gather_rows(&idx);
        assert_eq!(g.rows, 3);
        assert_eq!(g.row(1), x.row(7));
        let mut acc = Matrix::zeros(10, 4);
        acc.scatter_add_rows(&idx, &g, &[1.0, 2.0, 1.0]);
        for c in 0..4 {
            assert!((acc.at(7, c) - 2.0 * x.at(7, c)).abs() < 1e-6);
            assert_eq!(acc.at(0, c), 0.0);
        }
    }

    #[test]
    fn l2_distance_zero_iff_equal() {
        let mut rng = Rng::new(7);
        let a = Matrix::randn(5, 5, 1.0, &mut rng);
        assert_eq!(a.l2_distance(&a), 0.0);
        let mut b = a.clone();
        b.data[0] += 3.0;
        assert!((a.l2_distance(&b) - 3.0).abs() < 1e-6);
    }
}
