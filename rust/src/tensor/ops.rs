//! Elementwise / row-wise neural-net ops on [`Matrix`].

use super::Matrix;

/// In-place row-wise softmax (router gating).
pub fn softmax_rows(x: &mut Matrix) {
    for r in 0..x.rows {
        let row = x.row_mut(r);
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        let inv = 1.0 / z;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// SiLU (swish) activation: `x * sigmoid(x)` — the σ in the paper's Eq. 1.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Row-wise RMSNorm with learned gain.
pub fn rmsnorm(x: &Matrix, gain: &[f32], eps: f32) -> Matrix {
    assert_eq!(x.cols, gain.len());
    let mut out = Matrix::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let ms = row.iter().map(|v| v * v).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        let orow = out.row_mut(r);
        for c in 0..x.cols {
            orow[c] = row[c] * inv * gain[c];
        }
    }
    out
}

/// Top-k indices + values of a slice, descending (router top-k).
pub fn topk(xs: &[f32], k: usize) -> Vec<(usize, f32)> {
    assert!(k <= xs.len());
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    // partial selection: k is tiny (≤8) so a simple selection pass is fine
    for i in 0..k {
        let mut best = i;
        for j in i + 1..xs.len() {
            if xs[idx[j]] > xs[idx[best]] {
                best = j;
            }
        }
        idx.swap(i, best);
    }
    idx[..k].iter().map(|&i| (i, xs[i])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(1);
        let mut x = Matrix::randn(6, 9, 3.0, &mut rng);
        softmax_rows(&mut x);
        for r in 0..x.rows {
            let s: f32 = x.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(x.row(r).iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let mut a = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let mut b = Matrix::from_vec(1, 3, vec![101.0, 102.0, 103.0]);
        softmax_rows(&mut a);
        softmax_rows(&mut b);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn silu_known_values() {
        assert_eq!(silu(0.0), 0.0);
        assert!((silu(1.0) - 0.731058).abs() < 1e-4);
        assert!(silu(-20.0).abs() < 1e-6);
    }

    #[test]
    fn rmsnorm_unit_rows() {
        let x = Matrix::from_vec(1, 4, vec![2.0, 2.0, 2.0, 2.0]);
        let out = rmsnorm(&x, &[1.0; 4], 1e-6);
        for &v in &out.data {
            assert!((v - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn topk_descending() {
        let xs = [0.1, 0.9, 0.5, 0.7];
        let t = topk(&xs, 3);
        assert_eq!(t[0].0, 1);
        assert_eq!(t[1].0, 3);
        assert_eq!(t[2].0, 2);
    }

    #[test]
    fn topk_full_is_sort() {
        let xs = [3.0, 1.0, 2.0];
        let t = topk(&xs, 3);
        assert_eq!(t.iter().map(|p| p.0).collect::<Vec<_>>(), vec![0, 2, 1]);
    }
}
