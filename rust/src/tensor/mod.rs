//! Dense f32 tensor substrate.
//!
//! The native (non-PJRT) compute path — calibration forward passes, GPTQ,
//! perplexity evaluation — runs on this small row-major matrix type. The
//! matmul is cache-blocked and multithreaded (see [`matmul`]); everything
//! else is straightforward elementwise code.

pub mod matrix;
pub mod ops;

pub use matrix::Matrix;
pub use ops::{rmsnorm, silu, softmax_rows};
