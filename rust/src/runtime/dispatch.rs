//! Grouped mixed-precision GroupGEMM dispatch (DESIGN.md §GroupGEMM-Dispatch).
//!
//! The paper's headline system artifact is a GroupGEMM kernel that executes
//! sub-GEMMs of *different* precisions in parallel on one GPU (§4). The
//! serving analogue here is a plan → wave → execute → scatter pipeline
//! replacing the engine's old expert-at-a-time loop:
//!
//! 1. **Plan** ([`DispatchPlan::plan`]): every routed (expert, tile) work
//!    item for a whole MoE block is gathered up front, each expert's row
//!    count decomposed into exported tile sizes via
//!    [`tile_decompose`](super::tile_decompose).
//! 2. **Waves** — items are bucketed by `(RuntimeScheme, tile_m)`: all
//!    members of a wave run the *same* AOT executable, mirroring one
//!    same-shape group of the paper's GroupGEMM. Waves are ordered
//!    longest-first (LPT) so the slowest bucket starts earliest.
//! 3. **Execute** ([`execute`]): every item across all waves runs
//!    concurrently on scoped worker threads
//!    ([`parallel_for_with_state`](crate::util::threadpool::parallel_for_with_state)),
//!    so PJRT executions of different precisions are in flight
//!    simultaneously — the costmodel's "parallel mixed-precision
//!    GroupGEMM" assumption, finally true on the real execution path.
//!    Full tiles execute zero-copy out of the gathered input; only a
//!    ragged final tile is padded, into a per-worker scratch buffer that
//!    is reused across waves.
//! 4. **Scatter** — the caller (engine) folds item outputs back with the
//!    routing weights in a fixed order, so grouped results are bit-for-bit
//!    identical to sequential dispatch regardless of worker count.
//!
//! Everything except [`execute`] is pure and unit-tests without a PJRT
//! runtime; the batcher's fill estimation also feeds off [`fill_estimate`]
//! instead of re-deriving tile math.

use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use crate::tensor::Matrix;
use crate::util::threadpool::parallel_for_with_state;

use super::{tile_decompose, Runtime, RuntimeScheme};

/// How the engine runs a block's expert FFNs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum DispatchMode {
    /// Legacy expert-at-a-time, tile-at-a-time loop (reference path).
    Sequential,
    /// Plan → wave → concurrent execute → ordered scatter (this module).
    #[default]
    Grouped,
}

/// One expert's share of a block dispatch, as handed to the planner:
/// `rows` routed tokens (already gathered contiguously) to run under
/// `scheme`. `expert` is the slot index (routed experts first, then
/// shared).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExpertWork {
    pub expert: usize,
    pub scheme: RuntimeScheme,
    pub rows: usize,
}

/// One tile-sized unit of work: rows `[row0, row0 + rows)` of work entry
/// `input`'s gathered matrix, executed by the `(scheme, tile_m)`
/// executable. `rows < tile_m` only on a ragged final tile (padded).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkItem {
    /// Index into the planner's input slice (and the executor's
    /// [`ExpertInput`] slice).
    pub input: usize,
    /// Slot index, copied from the work entry for scatter bookkeeping.
    pub expert: usize,
    pub scheme: RuntimeScheme,
    pub tile_m: usize,
    pub row0: usize,
    pub rows: usize,
}

/// All items sharing one executable — one same-shape group of the
/// GroupGEMM. `items` are indices into [`DispatchPlan::items`], in
/// planning order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Wave {
    pub scheme: RuntimeScheme,
    pub tile_m: usize,
    pub items: Vec<usize>,
}

impl Wave {
    /// Rows shipped to PJRT by this wave, padding included.
    pub fn padded_rows(&self) -> usize {
        self.items.len() * self.tile_m
    }
}

/// The planned dispatch of one MoE block: flat work items plus their
/// wave grouping. Plans are deterministic functions of the work list.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DispatchPlan {
    pub items: Vec<WorkItem>,
    pub waves: Vec<Wave>,
}

impl DispatchPlan {
    /// Decompose every work entry into exported tiles and bucket the tiles
    /// into waves. Wave order is longest-projected-first (total padded
    /// rows, descending) with a fixed tie-break, so execution starts the
    /// heaviest bucket earliest and plans are reproducible.
    pub fn plan(work: &[ExpertWork]) -> DispatchPlan {
        let mut items = Vec::new();
        for (wi, w) in work.iter().enumerate() {
            let mut row0 = 0usize;
            for tile_m in tile_decompose(w.rows) {
                let rows = (w.rows - row0).min(tile_m);
                items.push(WorkItem {
                    input: wi,
                    expert: w.expert,
                    scheme: w.scheme,
                    tile_m,
                    row0,
                    rows,
                });
                row0 += rows;
            }
        }
        let mut waves: Vec<Wave> = Vec::new();
        for (ii, it) in items.iter().enumerate() {
            match waves.iter_mut().find(|wv| wv.scheme == it.scheme && wv.tile_m == it.tile_m) {
                Some(wv) => wv.items.push(ii),
                None => waves.push(Wave {
                    scheme: it.scheme,
                    tile_m: it.tile_m,
                    items: vec![ii],
                }),
            }
        }
        waves.sort_by(|a, b| {
            b.padded_rows()
                .cmp(&a.padded_rows())
                .then(b.tile_m.cmp(&a.tile_m))
                .then(a.scheme.name().cmp(b.scheme.name()))
        });
        DispatchPlan { items, waves }
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Rows shipped to PJRT, padding included.
    pub fn padded_rows(&self) -> usize {
        self.items.iter().map(|i| i.tile_m).sum()
    }

    /// Useful (non-padding) rows.
    pub fn useful_rows(&self) -> usize {
        self.items.iter().map(|i| i.rows).sum()
    }

    /// Useful fraction of shipped rows, in `[0, 1]` (1.0 for empty plans).
    pub fn fill_ratio(&self) -> f64 {
        let padded = self.padded_rows();
        if padded == 0 {
            return 1.0;
        }
        self.useful_rows() as f64 / padded as f64
    }
}

/// Planner-derived tile fill for `m` concatenated rows — what the batcher
/// uses to size batches against the exported tile set without re-deriving
/// tile math.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FillEstimate {
    pub tiles: usize,
    pub padded_rows: usize,
    pub useful_rows: usize,
}

impl FillEstimate {
    /// Useful fraction of shipped rows (1.0 when nothing is queued).
    pub fn fill_ratio(&self) -> f64 {
        if self.padded_rows == 0 {
            return 1.0;
        }
        self.useful_rows as f64 / self.padded_rows as f64
    }
}

/// Estimate the tile fill of dispatching `m` rows through one executable
/// family (scheme-independent: every family ships the same tile grid).
pub fn fill_estimate(m: usize) -> FillEstimate {
    let tiles = tile_decompose(m);
    FillEstimate {
        tiles: tiles.len(),
        padded_rows: tiles.iter().sum(),
        useful_rows: m,
    }
}

/// The executor-side view of one work entry: the expert's gathered input
/// rows and its prepared weight literals. Indexed by [`WorkItem::input`].
pub struct ExpertInput<'a> {
    pub x: &'a Matrix,
    pub literals: &'a [xla::Literal],
}

/// Per-wave execution record.
#[derive(Clone, Copy, Debug)]
pub struct WaveStats {
    pub scheme: RuntimeScheme,
    pub tile_m: usize,
    pub items: usize,
    pub padded_rows: usize,
    pub useful_rows: usize,
    /// First launch of any member, relative to dispatch start (trace
    /// placement of the wave span).
    pub start_s: f64,
    /// First-launch → last-completion wall clock of the wave's members.
    pub elapsed_s: f64,
    /// Sum of member execute times (busy time; > `elapsed_s` means the
    /// wave genuinely overlapped with itself or with other waves).
    pub busy_s: f64,
}

/// Execution record of one grouped block dispatch.
#[derive(Clone, Debug, Default)]
pub struct WaveReport {
    pub waves: Vec<WaveStats>,
    /// Whole-dispatch wall clock.
    pub elapsed_s: f64,
}

impl WaveReport {
    pub fn items(&self) -> usize {
        self.waves.iter().map(|w| w.items).sum()
    }

    pub fn padded_rows(&self) -> usize {
        self.waves.iter().map(|w| w.padded_rows).sum()
    }

    pub fn useful_rows(&self) -> usize {
        self.waves.iter().map(|w| w.useful_rows).sum()
    }
}

/// Per-item completion: output (cropped to useful rows) + launch/finish
/// timestamps relative to dispatch start.
type ItemSlot = Option<(Result<Matrix>, f64, f64)>;

/// Shared read-only state for the scoped dispatch workers.
///
/// SAFETY: the xla-rs binding types wrap raw pointers and never declare
/// `Send`/`Sync`, but the PJRT C API guarantees the surface used here is
/// thread-safe: concurrent `Execute` calls (even on the same loaded
/// executable) and concurrent read-only literal access. All `Runtime`
/// cache mutation is behind its own mutex (or the frozen snapshot), and
/// each worker writes only its own item slots, which carry their own
/// locks. This impl asserts exactly that and nothing more.
struct Shared<'a> {
    rt: &'a Runtime,
    plan: &'a DispatchPlan,
    inputs: &'a [ExpertInput<'a>],
    order: &'a [usize],
    results: &'a [Mutex<ItemSlot>],
    start: Instant,
}
unsafe impl Sync for Shared<'_> {}

/// Run every item of `plan` concurrently (wave-major issue order, dynamic
/// self-scheduling over `threads` scoped workers) and return the per-item
/// outputs, cropped to useful rows, plus per-wave timing. Outputs are
/// positionally aligned with `plan.items`; results do not depend on
/// `threads`.
pub fn execute(
    rt: &Runtime,
    plan: &DispatchPlan,
    inputs: &[ExpertInput<'_>],
    threads: usize,
) -> Result<(Vec<Matrix>, WaveReport)> {
    if plan.is_empty() {
        return Ok((Vec::new(), WaveReport::default()));
    }
    assert!(
        plan.items.iter().all(|it| it.input < inputs.len()),
        "dispatch plan references inputs beyond the provided slice"
    );
    // wave-major issue order: heavy waves first (plan already LPT-sorted)
    let order: Vec<usize> = plan.waves.iter().flat_map(|w| w.items.iter().copied()).collect();
    debug_assert_eq!(order.len(), plan.items.len());
    let results: Vec<Mutex<ItemSlot>> = plan.items.iter().map(|_| Mutex::new(None)).collect();
    let max_tile = plan.items.iter().map(|i| i.tile_m).max().unwrap_or(0);
    let scratch_cap = max_tile * inputs.first().map_or(0, |i| i.x.cols);
    let shared = Shared { rt, plan, inputs, order: &order, results: &results, start: Instant::now() };
    let shared = &shared;
    parallel_for_with_state(
        order.len(),
        threads,
        // one padded-tile scratch buffer per worker, reused across waves
        move || Vec::<f32>::with_capacity(scratch_cap),
        |scratch, k| {
            let it = &shared.plan.items[shared.order[k]];
            let inp = &shared.inputs[it.input];
            let hidden = inp.x.cols;
            let t0 = shared.start.elapsed().as_secs_f64();
            let res = if it.rows == it.tile_m {
                // whole tile: execute straight out of the gathered matrix
                shared.rt.run_expert_ffn_rows(
                    it.scheme,
                    it.tile_m,
                    hidden,
                    &inp.x.data[it.row0 * hidden..(it.row0 + it.tile_m) * hidden],
                    inp.literals,
                )
            } else {
                // ragged final tile: pad into the worker's scratch buffer
                scratch.clear();
                scratch.resize(it.tile_m * hidden, 0.0);
                scratch[..it.rows * hidden]
                    .copy_from_slice(&inp.x.data[it.row0 * hidden..(it.row0 + it.rows) * hidden]);
                shared.rt.run_expert_ffn_rows(
                    it.scheme,
                    it.tile_m,
                    hidden,
                    &scratch[..],
                    inp.literals,
                )
            };
            let t1 = shared.start.elapsed().as_secs_f64();
            // crop the tile output to its useful rows without copying
            let res = res.map(|m| {
                let cols = m.cols;
                let mut data = m.data;
                data.truncate(it.rows * cols);
                Matrix::from_vec(it.rows, cols, data)
            });
            *shared.results[shared.order[k]].lock().unwrap() = Some((res, t0, t1));
        },
    );
    let elapsed_s = shared.start.elapsed().as_secs_f64();

    // unpack in item order so the first failure reported is deterministic
    let mut outputs = Vec::with_capacity(plan.items.len());
    let mut timings = Vec::with_capacity(plan.items.len());
    for slot in results {
        let (res, t0, t1) = slot
            .into_inner()
            .unwrap()
            .expect("dispatch worker skipped an item");
        outputs.push(res?);
        timings.push((t0, t1));
    }
    let waves = plan
        .waves
        .iter()
        .map(|w| {
            let first = w.items.iter().map(|&i| timings[i].0).fold(f64::INFINITY, f64::min);
            let last = w.items.iter().map(|&i| timings[i].1).fold(0.0f64, f64::max);
            WaveStats {
                scheme: w.scheme,
                tile_m: w.tile_m,
                items: w.items.len(),
                padded_rows: w.padded_rows(),
                useful_rows: w.items.iter().map(|&i| plan.items[i].rows).sum(),
                start_s: if first.is_finite() { first } else { 0.0 },
                elapsed_s: (last - first).max(0.0),
                busy_s: w.items.iter().map(|&i| timings[i].1 - timings[i].0).sum(),
            }
        })
        .collect();
    Ok((outputs, WaveReport { waves, elapsed_s }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::TILE_MS;

    fn work(entries: &[(usize, RuntimeScheme, usize)]) -> Vec<ExpertWork> {
        entries
            .iter()
            .map(|&(expert, scheme, rows)| ExpertWork { expert, scheme, rows })
            .collect()
    }

    #[test]
    fn plan_covers_every_row_exactly_once() {
        let w = work(&[
            (0, RuntimeScheme::Fp16, 68),
            (1, RuntimeScheme::W4A16, 5),
            (2, RuntimeScheme::W8A8, 340),
            (4, RuntimeScheme::W4A4, 1),
        ]);
        let plan = DispatchPlan::plan(&w);
        for (wi, entry) in w.iter().enumerate() {
            let mut covered = 0usize;
            for it in plan.items.iter().filter(|it| it.input == wi) {
                assert_eq!(it.expert, entry.expert);
                assert_eq!(it.scheme, entry.scheme);
                assert_eq!(it.row0, covered, "tiles must be in row order");
                assert!(it.rows >= 1 && it.rows <= it.tile_m);
                assert!(TILE_MS.contains(&it.tile_m));
                covered += it.rows;
            }
            assert_eq!(covered, entry.rows);
        }
        assert_eq!(plan.useful_rows(), 68 + 5 + 340 + 1);
        assert_eq!(
            plan.padded_rows(),
            w.iter().map(|e| tile_decompose(e.rows).iter().sum::<usize>()).sum::<usize>()
        );
    }

    #[test]
    fn waves_bucket_by_scheme_and_tile() {
        // two experts share (fp16, 64) — must land in one wave
        let w = work(&[
            (0, RuntimeScheme::Fp16, 64),
            (1, RuntimeScheme::Fp16, 64),
            (2, RuntimeScheme::W8A8, 64),
            (3, RuntimeScheme::Fp16, 4),
        ]);
        let plan = DispatchPlan::plan(&w);
        assert_eq!(plan.waves.len(), 3);
        let fp16_64 = plan
            .waves
            .iter()
            .find(|wv| wv.scheme == RuntimeScheme::Fp16 && wv.tile_m == 64)
            .unwrap();
        assert_eq!(fp16_64.items.len(), 2);
        // every item appears in exactly one wave
        let mut seen: Vec<usize> = plan.waves.iter().flat_map(|wv| wv.items.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..plan.items.len()).collect::<Vec<_>>());
    }

    #[test]
    fn wave_order_is_deterministic_and_lpt() {
        let w = work(&[
            (0, RuntimeScheme::Fp16, 4),
            (1, RuntimeScheme::W4A4, 256),
            (2, RuntimeScheme::W8A8, 64),
        ]);
        let a = DispatchPlan::plan(&w);
        let b = DispatchPlan::plan(&w);
        assert_eq!(a, b, "planning must be reproducible");
        let loads: Vec<usize> = a.waves.iter().map(|wv| wv.padded_rows()).collect();
        assert!(loads.windows(2).all(|p| p[0] >= p[1]), "waves not LPT-sorted: {loads:?}");
    }

    #[test]
    fn mixed_precision_block_produces_concurrent_waves() {
        // the bench's acceptance scenario: 4 runtime families live in one
        // block ⇒ ≥ 4 waves planned for one concurrent dispatch
        let w = work(&[
            (0, RuntimeScheme::Fp16, 68),
            (1, RuntimeScheme::W4A16, 68),
            (2, RuntimeScheme::W8A8, 68),
            (3, RuntimeScheme::W4A4, 68),
        ]);
        let plan = DispatchPlan::plan(&w);
        assert!(plan.waves.len() >= 4, "only {} waves", plan.waves.len());
        assert!(plan.fill_ratio() > 0.9, "68 → 64+4 should be fully dense");
    }

    #[test]
    fn zero_row_entries_and_empty_work() {
        let plan = DispatchPlan::plan(&[]);
        assert!(plan.is_empty());
        assert_eq!(plan.fill_ratio(), 1.0);
        let plan = DispatchPlan::plan(&work(&[(0, RuntimeScheme::Fp16, 0)]));
        assert!(plan.is_empty(), "0-row experts plan no items");
    }

    #[test]
    fn fill_estimate_matches_decomposition() {
        for m in 0..=600usize {
            let est = fill_estimate(m);
            let tiles = tile_decompose(m);
            assert_eq!(est.tiles, tiles.len());
            assert_eq!(est.padded_rows, tiles.iter().sum::<usize>());
            assert_eq!(est.useful_rows, m);
            assert!(est.fill_ratio() > 0.0 && est.fill_ratio() <= 1.0);
        }
        assert_eq!(fill_estimate(0).fill_ratio(), 1.0);
        assert_eq!(fill_estimate(68).padded_rows, 68);
        assert_eq!(fill_estimate(3).padded_rows, 4);
    }
}
