//! PJRT runtime: loads AOT-compiled HLO text artifacts and executes them on
//! the request path. Python never runs here — `make artifacts` produced the
//! HLO once at build time (see `python/compile/aot.py`).
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation` → `client.compile`.

pub mod dispatch;
pub mod expert_weights;

pub use dispatch::{DispatchMode, DispatchPlan, ExpertWork, Wave, WaveReport, WaveStats, WorkItem};
pub use expert_weights::{PreparedExpert, QuantPayload, QuantizedExpertData};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};

use anyhow::{bail, Result};

use crate::tensor::Matrix;

/// Runtime scheme families shipped as executables (perf-path set; exotic
/// accuracy-side schemes are evaluated natively, never served).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RuntimeScheme {
    Fp16,
    W4A16,
    W8A8,
    W4A4,
}

impl RuntimeScheme {
    pub fn name(self) -> &'static str {
        match self {
            RuntimeScheme::Fp16 => "fp16",
            RuntimeScheme::W4A16 => "w4a16",
            RuntimeScheme::W8A8 => "w8a8",
            RuntimeScheme::W4A4 => "w4a4",
        }
    }

    pub const ALL: [RuntimeScheme; 4] =
        [RuntimeScheme::Fp16, RuntimeScheme::W4A16, RuntimeScheme::W8A8, RuntimeScheme::W4A4];

    /// Map an allocator scheme to its runtime executable family.
    pub fn from_quant(s: &crate::quant::QuantScheme) -> RuntimeScheme {
        if s.is_fp16() {
            RuntimeScheme::Fp16
        } else if s.weight_only() {
            RuntimeScheme::W4A16
        } else if s.wbits <= 4 && s.abits <= 4 {
            RuntimeScheme::W4A4
        } else {
            RuntimeScheme::W8A8
        }
    }
}

/// Tile sizes the AOT export ships (`python/compile/aot.py::TILE_MS`).
pub const TILE_MS: [usize; 4] = [4, 16, 64, 256];

/// Smallest exported tile that fits `m` tokens (largest tile for overflow).
pub fn pick_tile(m: usize) -> usize {
    for t in TILE_MS {
        if m <= t {
            return t;
        }
    }
    *TILE_MS.last().unwrap()
}

/// Greedy decomposition of `m` rows into exported tile sizes: take the
/// largest whole tile that fits the remainder, so 68 tokens run as 64 + 4
/// instead of one padded 256-tile (§Perf: padding 98% → ~2% on the serving
/// path). Only the final tile can carry padding, and that padding is always
/// `< TILE_MS[0]` rows. Shared by the engine's expert dispatch and the
/// batcher's fill estimation.
pub fn tile_decompose(m: usize) -> Vec<usize> {
    let mut tiles = Vec::new();
    let mut rem = m;
    while rem > 0 {
        let t = TILE_MS
            .iter()
            .rev()
            .copied()
            .find(|&t| t <= rem)
            .unwrap_or_else(|| pick_tile(rem));
        tiles.push(t);
        rem -= rem.min(t);
    }
    tiles
}

/// Padding rows a decomposition of `m` would ship (batcher fill metric).
pub fn tile_padding(m: usize) -> usize {
    tile_decompose(m).iter().sum::<usize>() - m
}

/// PJRT client + executable cache.
///
/// The cache is read-mostly: after [`warmup_expert_ffn`](Runtime::warmup_expert_ffn)
/// compiles the full (scheme, tile) grid it is frozen into an immutable
/// snapshot, and every hot-path lookup hits that snapshot without taking a
/// lock — the grouped dispatcher resolves executables from many worker
/// threads at once. Names missing from the snapshot (cold artifacts like
/// `smoke_matmul`) fall back to the mutex-guarded build path.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    frozen: OnceLock<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// CPU PJRT client over an artifacts directory.
    pub fn cpu(artifacts_dir: &Path) -> Result<Runtime> {
        if !artifacts_dir.exists() {
            bail!("artifacts dir {artifacts_dir:?} missing — run `make artifacts`");
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu: {e}"))?;
        Ok(Runtime {
            client,
            dir: artifacts_dir.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
            frozen: OnceLock::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile (cached) an executable by artifact stem, e.g.
    /// `expert_ffn_w4a16_m64`. Lock-free once the cache is frozen.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(snap) = self.frozen.get() {
            if let Some(e) = snap.get(name) {
                return Ok(e.clone());
            }
        }
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Snapshot the compiled-executable cache into the lock-free read path.
    /// Idempotent; the first snapshot wins (later compiles still serve
    /// through the mutex path).
    pub fn freeze_cache(&self) {
        let snap = self.cache.lock().unwrap().clone();
        let _ = self.frozen.set(snap);
    }

    /// Client-per-replica construction: a CPU PJRT client with the full
    /// (scheme, tile) executable grid pre-compiled and the cache frozen.
    /// Every serving replica builds its own — executables are compiled
    /// per client and never shared across engine threads, which is what
    /// keeps the non-`Send` constraint per-replica instead of global.
    pub fn cpu_warmed(artifacts_dir: &Path) -> Result<Runtime> {
        let rt = Runtime::cpu(artifacts_dir)?;
        rt.warmup_expert_ffn()?;
        Ok(rt)
    }

    /// Pre-compile every (scheme, tile) expert executable (hot-path
    /// warmup), then freeze the cache so dispatch lookups are lock-free.
    pub fn warmup_expert_ffn(&self) -> Result<usize> {
        let mut n = 0;
        for s in RuntimeScheme::ALL {
            for m in TILE_MS {
                self.executable(&format!("expert_ffn_{}_m{}", s.name(), m))?;
                n += 1;
            }
        }
        self.freeze_cache();
        Ok(n)
    }

    /// Execute an expert-FFN executable: `x` tile + prepared weight
    /// literals; returns the `[m, hidden]` output.
    pub fn run_expert_ffn(
        &self,
        scheme: RuntimeScheme,
        tile_m: usize,
        x: &Matrix,
        weights: &[xla::Literal],
    ) -> Result<Matrix> {
        assert_eq!(x.rows, tile_m);
        self.run_expert_ffn_rows(scheme, tile_m, x.cols, &x.data, weights)
    }

    /// As [`run_expert_ffn`](Runtime::run_expert_ffn) over a raw row-major
    /// `[tile_m, hidden]` slice — the grouped dispatcher's entry point: a
    /// full tile executes straight out of the caller's gathered matrix
    /// (zero copy), only ragged final tiles go through a padded scratch
    /// buffer first.
    pub fn run_expert_ffn_rows(
        &self,
        scheme: RuntimeScheme,
        tile_m: usize,
        hidden: usize,
        xdata: &[f32],
        weights: &[xla::Literal],
    ) -> Result<Matrix> {
        assert_eq!(xdata.len(), tile_m * hidden);
        let exe = self.executable(&format!("expert_ffn_{}_m{}", scheme.name(), tile_m))?;
        let x_lit = lit_f32(&[tile_m, hidden], xdata)?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(1 + weights.len());
        args.push(&x_lit);
        args.extend(weights.iter());
        let result = exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow::anyhow!("tuple: {e}"))?;
        let vals = out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))?;
        let cols = vals.len() / tile_m;
        Ok(Matrix::from_vec(tile_m, cols, vals))
    }
}

// ---------------- literal helpers ----------------

/// Reinterpret a typed slice as raw bytes without copying. Sound for the
/// plain-old-data element types used below (f32, i8); the literal
/// constructor copies out of the borrow before it returns.
fn as_bytes<T: Copy>(data: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(data.as_ptr().cast::<u8>(), std::mem::size_of_val(data))
    }
}

/// f32 literal of the given shape. Single bulk copy of the payload: XLA
/// literals take host-native layout, and the per-call f32→bytes
/// `flat_map` this replaces dominated small-tile dispatch (see
/// `benches/bench_group_dispatch.rs` micro-guard). Big-endian hosts keep
/// the explicit little-endian conversion — the AOT artifacts are LE.
pub fn lit_f32(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
    assert_eq!(dims.iter().product::<usize>(), data.len());
    #[cfg(target_endian = "big")]
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    #[cfg(target_endian = "big")]
    let bytes: &[u8] = &bytes;
    #[cfg(target_endian = "little")]
    let bytes: &[u8] = as_bytes(data);
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .map_err(|e| anyhow::anyhow!("lit_f32: {e}"))
}

/// int8 literal (bulk reinterpretation, endianness-free).
pub fn lit_i8(dims: &[usize], data: &[i8]) -> Result<xla::Literal> {
    assert_eq!(dims.iter().product::<usize>(), data.len());
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S8, dims, as_bytes(data))
        .map_err(|e| anyhow::anyhow!("lit_i8: {e}"))
}

/// uint8 literal (packed low-bit weights).
pub fn lit_u8(dims: &[usize], data: &[u8]) -> Result<xla::Literal> {
    assert_eq!(dims.iter().product::<usize>(), data.len());
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U8, dims, data)
        .map_err(|e| anyhow::anyhow!("lit_u8: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_tile_rounds_up() {
        assert_eq!(pick_tile(1), 4);
        assert_eq!(pick_tile(16), 16);
        assert_eq!(pick_tile(5), 16);
        assert_eq!(pick_tile(17), 64);
        assert_eq!(pick_tile(300), 256);
    }

    #[test]
    fn tile_decompose_covers_exactly_with_minimal_padding() {
        for m in 1..=600usize {
            let tiles = tile_decompose(m);
            let total: usize = tiles.iter().sum();
            // covers m
            assert!(total >= m, "m={m}: tiles {tiles:?} cover only {total}");
            // minimal padding: strictly less than the smallest exported tile
            assert!(
                total - m < TILE_MS[0],
                "m={m}: {} padding rows with tiles {tiles:?}",
                total - m
            );
            // every tile is an exported size
            assert!(tiles.iter().all(|t| TILE_MS.contains(t)), "m={m}: {tiles:?}");
            // greedy ⇒ non-increasing tile sizes
            assert!(tiles.windows(2).all(|w| w[0] >= w[1]), "m={m}: {tiles:?}");
            assert_eq!(tile_padding(m), total - m);
        }
        assert!(tile_decompose(0).is_empty());
    }

    #[test]
    fn tile_decompose_matches_hand_cases() {
        assert_eq!(tile_decompose(68), vec![64, 4]);
        assert_eq!(tile_decompose(256), vec![256]);
        assert_eq!(tile_decompose(3), vec![4]); // 1 padding row
        assert_eq!(tile_decompose(340), vec![256, 64, 16, 4]);
    }

    #[test]
    fn scheme_mapping() {
        use crate::quant::QuantScheme;
        assert_eq!(RuntimeScheme::from_quant(&QuantScheme::FP16), RuntimeScheme::Fp16);
        assert_eq!(RuntimeScheme::from_quant(&QuantScheme::W2A16G128), RuntimeScheme::W4A16);
        assert_eq!(RuntimeScheme::from_quant(&QuantScheme::W8A8), RuntimeScheme::W8A8);
        assert_eq!(RuntimeScheme::from_quant(&QuantScheme::W4A4G128), RuntimeScheme::W4A4);
        assert_eq!(RuntimeScheme::from_quant(&QuantScheme::W5A5), RuntimeScheme::W8A8);
    }

    #[test]
    fn bulk_literal_bytes_match_per_element_conversion() {
        // the single-memcpy payload must be byte-identical to the old
        // per-element construction (little-endian hosts)
        let data: Vec<f32> = (0..257).map(|i| (i as f32) * 0.37 - 3.0).collect();
        let per_element: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        #[cfg(target_endian = "little")]
        assert_eq!(as_bytes(&data), &per_element[..]);
        #[cfg(target_endian = "big")]
        let _ = per_element;
        let signed: Vec<i8> = (-128i8..=127).collect();
        let old: Vec<u8> = signed.iter().map(|&v| v as u8).collect();
        assert_eq!(as_bytes(&signed), &old[..]);
    }

    #[test]
    fn smoke_artifact_executes() {
        let Some(dir) = crate::harness::require_artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = Runtime::cpu(&dir).unwrap();
        let exe = rt.executable("smoke_matmul").unwrap();
        let x = lit_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        let y = lit_f32(&[2, 2], &[1.0, 1.0, 1.0, 1.0]).unwrap();
        let out = exe.execute::<&xla::Literal>(&[&x, &y]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple1()
            .unwrap();
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![5.0, 5.0, 9.0, 9.0]);
    }
}
