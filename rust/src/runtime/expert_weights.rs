//! Offline preparation of expert weights into the literal layouts the AOT
//! executables expect (mirrors `python/compile/model.py::prepare_expert_weights`
//! — pinned by `tests/runtime_expert_parity.rs`).

use anyhow::Result;

use crate::moe::ExpertWeights;
use crate::quant::pack::pack;
use crate::quant::uniform::{qparams, quantize_one};
use crate::tensor::Matrix;

use super::{lit_f32, lit_i8, lit_u8, RuntimeScheme};

/// One expert's weights, quantized and laid out for one runtime scheme.
pub struct PreparedExpert {
    pub scheme: RuntimeScheme,
    pub literals: Vec<xla::Literal>,
}

/// Per-channel asymmetric quantization of `[n, k]` → (packed u8, scales, zeros)
/// matching `ref.quantize_asym_grouped(w, bits, -1)` + `ref.pack_codes`.
fn asym_pack(w: &Matrix, bits: u8) -> Result<(Vec<u8>, Vec<f32>, Vec<f32>)> {
    let mut codes = Vec::with_capacity(w.rows * w.cols);
    let mut scales = Vec::with_capacity(w.rows);
    let mut zeros = Vec::with_capacity(w.rows);
    for r in 0..w.rows {
        let p = qparams(w.row(r), bits, false);
        for &v in w.row(r) {
            codes.push(quantize_one(v, &p));
        }
        scales.push(p.scale);
        zeros.push(p.zero);
    }
    Ok((pack(&codes, bits)?, scales, zeros))
}

/// Per-channel symmetric int codes + scales, matching `ref.quantize_sym`.
fn sym_codes(w: &Matrix, bits: u8) -> (Vec<i8>, Vec<f32>) {
    let mut codes = Vec::with_capacity(w.rows * w.cols);
    let mut scales = Vec::with_capacity(w.rows);
    for r in 0..w.rows {
        let p = qparams(w.row(r), bits, true);
        for &v in w.row(r) {
            codes.push(quantize_one(v, &p) as i8);
        }
        scales.push(p.scale);
    }
    (codes, scales)
}

impl PreparedExpert {
    /// Quantize + lay out one expert for `scheme`. Literal order matches
    /// `python/compile/model.py::example_args` (everything after `x`).
    pub fn prepare(e: &ExpertWeights, scheme: RuntimeScheme) -> Result<PreparedExpert> {
        let mut literals = Vec::new();
        match scheme {
            RuntimeScheme::Fp16 => {
                for w in [&e.gate, &e.up, &e.down] {
                    literals.push(lit_f32(&[w.rows, w.cols], &w.data)?);
                }
            }
            RuntimeScheme::W4A16 => {
                for w in [&e.gate, &e.up, &e.down] {
                    let (packed, scales, zeros) = asym_pack(w, 4)?;
                    literals.push(lit_u8(&[w.rows, w.cols / 2], &packed)?);
                    literals.push(lit_f32(&[w.rows, 1], &scales)?);
                    literals.push(lit_f32(&[w.rows, 1], &zeros)?);
                }
            }
            RuntimeScheme::W8A8 | RuntimeScheme::W4A4 => {
                let bits = if scheme == RuntimeScheme::W8A8 { 8 } else { 4 };
                for w in [&e.gate, &e.up, &e.down] {
                    let (codes, scales) = sym_codes(w, bits);
                    literals.push(lit_i8(&[w.rows, w.cols], &codes)?);
                    literals.push(lit_f32(&[w.rows, 1], &scales)?);
                }
            }
        }
        Ok(PreparedExpert { scheme, literals })
    }

    /// Native fake-quant twin of this preparation: what the executable
    /// computes, for parity checks and fallback execution.
    pub fn reference_forward(e: &ExpertWeights, scheme: RuntimeScheme, x: &Matrix) -> Matrix {
        use crate::quant::scheme::QuantScheme;
        use crate::quant::uniform::{fake_quant_matrix, fake_quant_rows_act};
        use crate::tensor::matrix::matmul_nt;
        use crate::tensor::ops::silu;
        let (wq, aq): (Box<dyn Fn(&Matrix) -> Matrix>, Box<dyn Fn(&Matrix) -> Matrix>) =
            match scheme {
                RuntimeScheme::Fp16 => (Box::new(|w| w.clone()), Box::new(|x| x.clone())),
                RuntimeScheme::W4A16 => (
                    Box::new(|w| fake_quant_matrix(w, 4, -1, false)),
                    Box::new(|x| x.clone()),
                ),
                RuntimeScheme::W8A8 => (
                    Box::new(|w| fake_quant_matrix(w, 8, -1, true)),
                    Box::new(|x| fake_quant_rows_act(x, 8, -1)),
                ),
                RuntimeScheme::W4A4 => (
                    Box::new(|w| fake_quant_matrix(w, 4, -1, true)),
                    Box::new(|x| fake_quant_rows_act(x, 4, -1)),
                ),
            };
        let _ = QuantScheme::FP16;
        let g = matmul_nt(&aq(x), &wq(&e.gate));
        let u = matmul_nt(&aq(x), &wq(&e.up));
        let mut h = Matrix::zeros(g.rows, g.cols);
        for i in 0..g.data.len() {
            h.data[i] = silu(g.data[i]) * u.data[i];
        }
        matmul_nt(&aq(&h), &wq(&e.down))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn asym_pack_shapes() {
        let mut rng = Rng::new(170);
        let w = Matrix::randn(8, 32, 1.0, &mut rng);
        let (packed, scales, zeros) = asym_pack(&w, 4).unwrap();
        assert_eq!(packed.len(), 8 * 16);
        assert_eq!(scales.len(), 8);
        assert_eq!(zeros.len(), 8);
    }

    #[test]
    fn sym_codes_in_range() {
        let mut rng = Rng::new(171);
        let w = Matrix::randn(4, 16, 2.0, &mut rng);
        let (codes, _) = sym_codes(&w, 4);
        assert!(codes.iter().all(|&c| (-8..=7).contains(&c)));
    }
}
