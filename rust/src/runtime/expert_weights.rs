//! Offline preparation of expert weights into the literal layouts the AOT
//! executables expect (mirrors `python/compile/model.py::prepare_expert_weights`
//! — pinned by `tests/runtime_expert_parity.rs`).

use anyhow::Result;

use crate::moe::ExpertWeights;
use crate::quant::pack::pack;
use crate::quant::uniform::{qparams, quantize_one};
use crate::tensor::Matrix;

use super::{lit_f32, lit_i8, lit_u8, RuntimeScheme};

/// One expert's weights, quantized and laid out for one runtime scheme.
pub struct PreparedExpert {
    pub scheme: RuntimeScheme,
    pub literals: Vec<xla::Literal>,
}

/// One literal-to-be, as plain host data. `Send`, unlike `xla::Literal`.
pub enum QuantPayload {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I8 { dims: Vec<usize>, data: Vec<i8> },
    U8 { dims: Vec<usize>, data: Vec<u8> },
}

/// The quantized layout of one expert *before* literal creation: plain
/// `Send` data, so the expensive re-quantization of a hot-swap can run on
/// a staging worker thread while the engine keeps serving. The engine
/// thread turns it into a [`PreparedExpert`] with
/// [`into_prepared`](Self::into_prepared) — literal creation is a bulk
/// memcpy, cheap enough for the serving thread.
pub struct QuantizedExpertData {
    pub scheme: RuntimeScheme,
    payloads: Vec<QuantPayload>,
}

impl QuantizedExpertData {
    /// Quantize + lay out one expert for `scheme` (the CPU-heavy half of
    /// [`PreparedExpert::prepare`], with no PJRT types involved). Payload
    /// order matches `python/compile/model.py::example_args` after `x`.
    pub fn quantize(e: &ExpertWeights, scheme: RuntimeScheme) -> Result<QuantizedExpertData> {
        let mut payloads = Vec::new();
        match scheme {
            RuntimeScheme::Fp16 => {
                for w in [&e.gate, &e.up, &e.down] {
                    payloads.push(QuantPayload::F32 {
                        dims: vec![w.rows, w.cols],
                        data: w.data.clone(),
                    });
                }
            }
            RuntimeScheme::W4A16 => {
                for w in [&e.gate, &e.up, &e.down] {
                    let (packed, scales, zeros) = asym_pack(w, 4)?;
                    payloads.push(QuantPayload::U8 {
                        dims: vec![w.rows, w.cols / 2],
                        data: packed,
                    });
                    payloads.push(QuantPayload::F32 { dims: vec![w.rows, 1], data: scales });
                    payloads.push(QuantPayload::F32 { dims: vec![w.rows, 1], data: zeros });
                }
            }
            RuntimeScheme::W8A8 | RuntimeScheme::W4A4 => {
                let bits = if scheme == RuntimeScheme::W8A8 { 8 } else { 4 };
                for w in [&e.gate, &e.up, &e.down] {
                    let (codes, scales) = sym_codes(w, bits);
                    payloads.push(QuantPayload::I8 {
                        dims: vec![w.rows, w.cols],
                        data: codes,
                    });
                    payloads.push(QuantPayload::F32 { dims: vec![w.rows, 1], data: scales });
                }
            }
        }
        Ok(QuantizedExpertData { scheme, payloads })
    }

    /// Materialize the PJRT literals (engine-thread half of a prepare).
    pub fn into_prepared(self) -> Result<PreparedExpert> {
        let mut literals = Vec::with_capacity(self.payloads.len());
        for p in self.payloads {
            literals.push(match p {
                QuantPayload::F32 { dims, data } => lit_f32(&dims, &data)?,
                QuantPayload::I8 { dims, data } => lit_i8(&dims, &data)?,
                QuantPayload::U8 { dims, data } => lit_u8(&dims, &data)?,
            });
        }
        Ok(PreparedExpert { scheme: self.scheme, literals })
    }
}

/// Per-channel asymmetric quantization of `[n, k]` → (packed u8, scales, zeros)
/// matching `ref.quantize_asym_grouped(w, bits, -1)` + `ref.pack_codes`.
fn asym_pack(w: &Matrix, bits: u8) -> Result<(Vec<u8>, Vec<f32>, Vec<f32>)> {
    let mut codes = Vec::with_capacity(w.rows * w.cols);
    let mut scales = Vec::with_capacity(w.rows);
    let mut zeros = Vec::with_capacity(w.rows);
    for r in 0..w.rows {
        let p = qparams(w.row(r), bits, false);
        for &v in w.row(r) {
            codes.push(quantize_one(v, &p));
        }
        scales.push(p.scale);
        zeros.push(p.zero);
    }
    Ok((pack(&codes, bits)?, scales, zeros))
}

/// Per-channel symmetric int codes + scales, matching `ref.quantize_sym`.
fn sym_codes(w: &Matrix, bits: u8) -> (Vec<i8>, Vec<f32>) {
    let mut codes = Vec::with_capacity(w.rows * w.cols);
    let mut scales = Vec::with_capacity(w.rows);
    for r in 0..w.rows {
        let p = qparams(w.row(r), bits, true);
        for &v in w.row(r) {
            codes.push(quantize_one(v, &p) as i8);
        }
        scales.push(p.scale);
    }
    (codes, scales)
}

impl PreparedExpert {
    /// Quantize + lay out one expert for `scheme`: the staging half
    /// ([`QuantizedExpertData::quantize`]) followed by literal creation.
    /// Literal order matches `python/compile/model.py::example_args`
    /// (everything after `x`).
    pub fn prepare(e: &ExpertWeights, scheme: RuntimeScheme) -> Result<PreparedExpert> {
        QuantizedExpertData::quantize(e, scheme)?.into_prepared()
    }

    /// Native fake-quant twin of this preparation: what the executable
    /// computes, for parity checks and fallback execution.
    pub fn reference_forward(e: &ExpertWeights, scheme: RuntimeScheme, x: &Matrix) -> Matrix {
        use crate::quant::scheme::QuantScheme;
        use crate::quant::uniform::{fake_quant_matrix, fake_quant_rows_act};
        use crate::tensor::matrix::matmul_nt;
        use crate::tensor::ops::silu;
        let (wq, aq): (Box<dyn Fn(&Matrix) -> Matrix>, Box<dyn Fn(&Matrix) -> Matrix>) =
            match scheme {
                RuntimeScheme::Fp16 => (Box::new(|w| w.clone()), Box::new(|x| x.clone())),
                RuntimeScheme::W4A16 => (
                    Box::new(|w| fake_quant_matrix(w, 4, -1, false)),
                    Box::new(|x| x.clone()),
                ),
                RuntimeScheme::W8A8 => (
                    Box::new(|w| fake_quant_matrix(w, 8, -1, true)),
                    Box::new(|x| fake_quant_rows_act(x, 8, -1)),
                ),
                RuntimeScheme::W4A4 => (
                    Box::new(|w| fake_quant_matrix(w, 4, -1, true)),
                    Box::new(|x| fake_quant_rows_act(x, 4, -1)),
                ),
            };
        let _ = QuantScheme::FP16;
        let g = matmul_nt(&aq(x), &wq(&e.gate));
        let u = matmul_nt(&aq(x), &wq(&e.up));
        let mut h = Matrix::zeros(g.rows, g.cols);
        for i in 0..g.data.len() {
            h.data[i] = silu(g.data[i]) * u.data[i];
        }
        matmul_nt(&aq(&h), &wq(&e.down))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn asym_pack_shapes() {
        let mut rng = Rng::new(170);
        let w = Matrix::randn(8, 32, 1.0, &mut rng);
        let (packed, scales, zeros) = asym_pack(&w, 4).unwrap();
        assert_eq!(packed.len(), 8 * 16);
        assert_eq!(scales.len(), 8);
        assert_eq!(zeros.len(), 8);
    }

    #[test]
    fn sym_codes_in_range() {
        let mut rng = Rng::new(171);
        let w = Matrix::randn(4, 16, 2.0, &mut rng);
        let (codes, _) = sym_codes(&w, 4);
        assert!(codes.iter().all(|&c| (-8..=7).contains(&c)));
    }

    #[test]
    fn quantized_expert_data_is_send_and_shapes_match_literal_order() {
        fn assert_send<T: Send>() {}
        assert_send::<QuantizedExpertData>();
        let mut rng = Rng::new(172);
        let e = ExpertWeights::random(32, 16, &mut rng);
        // fp16: 3 payloads (gate/up/down); w4a16: 9 (packed+scales+zeros
        // ×3); w8a8/w4a4: 6 (codes+scales ×3)
        for (scheme, n) in [
            (RuntimeScheme::Fp16, 3),
            (RuntimeScheme::W4A16, 9),
            (RuntimeScheme::W8A8, 6),
            (RuntimeScheme::W4A4, 6),
        ] {
            let q = QuantizedExpertData::quantize(&e, scheme).unwrap();
            assert_eq!(q.scheme, scheme);
            assert_eq!(q.payloads.len(), n, "{scheme:?}");
        }
        // fp16 payloads carry the raw weights verbatim
        let q = QuantizedExpertData::quantize(&e, RuntimeScheme::Fp16).unwrap();
        match &q.payloads[0] {
            QuantPayload::F32 { dims, data } => {
                assert_eq!(dims, &vec![16, 32]);
                assert_eq!(data, &e.gate.data);
            }
            _ => panic!("fp16 gate payload must be f32"),
        }
    }
}
