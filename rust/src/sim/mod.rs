//! Multi-SM execution simulator for MoE-block GEMM workloads.
//!
//! Executes [`ExecutionPlan`]s on a modeled GPU and reports wall-clock
//! estimates. Four execution styles reproduce the systems compared in
//! Fig. 2 / Fig. 5:
//!
//! * [`run_fused`] — MxMoE: one horizontally-fused launch, all tiles in one
//!   LPT-scheduled queue across SMs.
//! * [`run_sequential`] — vLLM-Marlin-MoE style: one launch per problem,
//!   full inter-launch serialization (wave-quantization waste emerges
//!   naturally when a problem has fewer tiles than SMs).
//! * [`run_unfused_dequant`] — HQQ style: a separate dequantization kernel
//!   materializes fp16 weights through HBM before every fp16 GEMM.
//! * fp16 baselines: build problems with `QuantScheme::FP16` and run either
//!   mode (fused fp16 = the CUTLASS Group-GEMM baseline).

use crate::costmodel::gpu::{gemm_ops, GpuSpec};
use crate::costmodel::micro::Specialization;
use crate::kernelgen::{fused_plan, sequential_plans, ExecutionPlan, GemmProblem};
use crate::quant::scheme::QuantScheme;

/// Simulation outcome.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Modeled wall-clock seconds.
    pub time: f64,
    /// Total tile count executed.
    pub tiles: usize,
    /// Kernel launches performed.
    pub launches: usize,
    /// Useful MACs ×2 (for throughput reporting).
    pub flops: f64,
}

impl SimReport {
    /// Effective throughput in TFLOP/s of useful (fp16-equivalent) work.
    pub fn tflops(&self) -> f64 {
        self.flops / self.time / 1e12
    }
}

fn useful_flops(problems: &[GemmProblem]) -> f64 {
    problems.iter().map(|p| gemm_ops(p.m, p.n, p.k)).sum()
}

/// Execute one launch under the launch-level roofline
/// (see `costmodel::tile::launch_roofline`).
pub fn launch_time(gpu: &GpuSpec, plan: &ExecutionPlan) -> f64 {
    crate::costmodel::tile::launch_roofline(gpu, &plan.compute_costs(), &plan.byte_costs())
}

/// Execute one fused plan: launch overhead + launch roofline.
pub fn run_plan(gpu: &GpuSpec, plan: &ExecutionPlan, flops: f64) -> SimReport {
    SimReport {
        time: gpu.launch_overhead * plan.launches as f64 + launch_time(gpu, plan),
        tiles: plan.tiles.len(),
        launches: plan.launches,
        flops,
    }
}

/// MxMoE fused mixed-precision Group-GEMM.
pub fn run_fused(gpu: &GpuSpec, problems: &[GemmProblem], spec: Specialization) -> SimReport {
    let plan = fused_plan(gpu, problems, spec);
    run_plan(gpu, &plan, useful_flops(problems))
}

/// Sequential per-problem launches (each problem's tiles scheduled alone —
/// small problems can't fill the GPU, and launches serialize).
pub fn run_sequential(gpu: &GpuSpec, problems: &[GemmProblem], spec: Specialization) -> SimReport {
    let plans = sequential_plans(gpu, problems, spec);
    let mut time = 0.0;
    let mut tiles = 0;
    for plan in &plans {
        time += gpu.launch_overhead + launch_time(gpu, plan);
        tiles += plan.tiles.len();
    }
    SimReport { time, tiles, launches: plans.len(), flops: useful_flops(problems) }
}

/// HQQ-style unfused path: for every problem, a dequant kernel reads the
/// quantized weight and writes fp16 weights to HBM, then an fp16 GEMM reads
/// them back. Two launches per problem.
pub fn run_unfused_dequant(gpu: &GpuSpec, problems: &[GemmProblem], spec: Specialization) -> SimReport {
    let mut time = 0.0;
    let mut tiles = 0;
    // fp16 GEMMs over the dequantized weights
    let fp16_problems: Vec<GemmProblem> = problems
        .iter()
        .map(|p| GemmProblem { scheme: QuantScheme::FP16, ..p.clone() })
        .collect();
    let plans = sequential_plans(gpu, &fp16_problems, spec);
    for (p, plan) in problems.iter().zip(&plans) {
        // dequant pass: read packed weights, write fp16 weights (bandwidth-bound)
        let read = p.scheme.avg_weight_bits(p.k) / 8.0 * (p.n * p.k) as f64;
        let write = 2.0 * (p.n * p.k) as f64;
        let dequant = (read + write) / gpu.mem_bw;
        time += 2.0 * gpu.launch_overhead + dequant + launch_time(gpu, plan);
        tiles += plan.tiles.len();
    }
    SimReport { time, tiles, launches: 2 * problems.len(), flops: useful_flops(problems) }
}

/// Replace every problem's scheme (uniform-precision helper for benches).
pub fn with_scheme(problems: &[GemmProblem], s: QuantScheme) -> Vec<GemmProblem> {
    problems.iter().map(|p| GemmProblem { scheme: s, ..p.clone() }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelgen::moe_problems;

    /// Fig. 2 workload: 60 experts [2816, 2048], 512 tokens, top-4.
    fn fig2_problems(scheme: QuantScheme) -> Vec<GemmProblem> {
        let tokens = vec![34usize; 60];
        let schemes = vec![[scheme; 3]; 60];
        moe_problems(&tokens, &schemes, 2048, 2816)
    }

    #[test]
    fn fig2_ordering_holds() {
        // paper Fig. 2: HQQ < fp16 ≤ sequential-Marlin < fused W4
        let gpu = GpuSpec::rtx4090();
        let sp = Specialization::Specialized;
        let fp16 = run_fused(&gpu, &fig2_problems(QuantScheme::FP16), sp);
        let hqq = run_unfused_dequant(&gpu, &fig2_problems(QuantScheme::W4A16), sp);
        let marlin_seq = run_sequential(&gpu, &fig2_problems(QuantScheme::W4A16), sp);
        let mx_w4 = run_fused(&gpu, &fig2_problems(QuantScheme::W4A16), sp);
        assert!(hqq.tflops() < fp16.tflops(), "HQQ {} !< fp16 {}", hqq.tflops(), fp16.tflops());
        assert!(marlin_seq.tflops() > fp16.tflops() * 0.8, "sequential w4 not competitive");
        assert!(mx_w4.tflops() > marlin_seq.tflops(), "fusion must beat sequential");
        assert!(
            mx_w4.tflops() > 1.5 * fp16.tflops(),
            "W4 fused {} vs fp16 {} — memory-bound speedup missing",
            mx_w4.tflops(),
            fp16.tflops()
        );
    }

    #[test]
    fn compute_bound_favors_w4a4() {
        // 8192 tokens: W4A4 > W8A8 > fp16 (Fig. 5 right panels)
        let gpu = GpuSpec::rtx4090();
        let sp = Specialization::Specialized;
        let tokens = vec![8192 * 4 / 60; 60];
        let mk = |s: QuantScheme| {
            let schemes = vec![[s; 3]; 60];
            moe_problems(&tokens, &schemes, 2048, 2816)
        };
        let t16 = run_fused(&gpu, &mk(QuantScheme::FP16), sp).tflops();
        let t8 = run_fused(&gpu, &mk(QuantScheme::W8A8), sp).tflops();
        let t4 = run_fused(&gpu, &mk(QuantScheme::W4A4), sp).tflops();
        assert!(t4 > t8 && t8 > t16, "{t4} {t8} {t16}");
        let speedup = t4 / t16;
        assert!(
            (2.0..5.0).contains(&speedup),
            "paper reports ~3–3.4× for compute-bound: got {speedup}"
        );
    }

    #[test]
    fn fused_beats_sequential_more_with_more_experts() {
        let gpu = GpuSpec::rtx4090();
        let sp = Specialization::Specialized;
        let gain = |experts: usize| {
            let tokens = vec![8usize; experts];
            let schemes = vec![[QuantScheme::W4A16; 3]; experts];
            let probs = moe_problems(&tokens, &schemes, 2048, 2816);
            run_sequential(&gpu, &probs, sp).time / run_fused(&gpu, &probs, sp).time
        };
        let g8 = gain(8);
        let g60 = gain(60);
        assert!(g60 > g8, "more experts ⇒ more fusion benefit ({g8} vs {g60})");
        assert!(g60 > 1.5, "fusion gain {g60}");
    }

    #[test]
    fn report_flops_independent_of_mode() {
        let gpu = GpuSpec::rtx4090();
        let sp = Specialization::Specialized;
        let probs = fig2_problems(QuantScheme::W4A16);
        let a = run_fused(&gpu, &probs, sp);
        let b = run_sequential(&gpu, &probs, sp);
        assert_eq!(a.flops, b.flops);
        assert!(a.time < b.time);
    }

    #[test]
    fn mixed_beats_uniform_when_skewed() {
        // the core co-design claim: with skewed activation, assigning
        // W4A16 to cold experts and W8A8 to hot experts beats uniform W8A8
        // (memory-bound tail) and uniform W4A16 (compute-bound head)
        let gpu = GpuSpec::rtx4090();
        let sp = Specialization::Specialized;
        // 8 hot experts with 400 tokens, 52 cold with 5
        let mut tokens = vec![5usize; 60];
        for e in 0..8 {
            tokens[e] = 400;
        }
        let uniform_w8 = {
            let schemes = vec![[QuantScheme::W8A8; 3]; 60];
            run_fused(&gpu, &moe_problems(&tokens, &schemes, 2048, 2816), sp)
        };
        let uniform_w4a16 = {
            let schemes = vec![[QuantScheme::W4A16; 3]; 60];
            run_fused(&gpu, &moe_problems(&tokens, &schemes, 2048, 2816), sp)
        };
        let mixed = {
            let mut schemes = vec![[QuantScheme::W4A16; 3]; 60];
            for e in 0..8 {
                schemes[e] = [QuantScheme::W8A8; 3];
            }
            run_fused(&gpu, &moe_problems(&tokens, &schemes, 2048, 2816), sp)
        };
        assert!(mixed.time < uniform_w8.time, "mixed {} !< W8A8 {}", mixed.time, uniform_w8.time);
        assert!(
            mixed.time < uniform_w4a16.time,
            "mixed {} !< W4A16 {}",
            mixed.time,
            uniform_w4a16.time
        );
    }
}
