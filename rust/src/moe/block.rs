//! The MoE block (Eq. 2) in full precision and in mixed precision.
//!
//! [`QuantizedMoeBlock`] is the accuracy-side realization of an MxMoE
//! allocation: every linear block `(expert, gate|up|down)` carries its own
//! [`QuantScheme`]; weights are (optionally Hadamard-rotated and) fake-
//! quantized offline with RTN or GPTQ, activations are fake-quantized
//! dynamically per token at each linear-block input, exactly mirroring what
//! the generated kernels do in integer arithmetic.

use anyhow::Result;

use crate::quant::hadamard::{random_signs, rotate_activations, rotate_weight};
use crate::quant::scheme::QuantScheme;
use crate::quant::uniform::fake_quant_rows_act;
use crate::quant::{gptq_quantize, rtn_quantize};
use crate::tensor::matrix::matmul_nt;
use crate::tensor::ops::silu;
use crate::tensor::Matrix;
use crate::util::Rng;

use super::expert::ExpertWeights;
use super::router::{route, Routing};

/// Which linear inside an expert (the paper's `j` index, N = 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinearKind {
    Gate = 0,
    Up = 1,
    Down = 2,
}

impl LinearKind {
    pub const ALL: [LinearKind; 3] = [LinearKind::Gate, LinearKind::Up, LinearKind::Down];

    pub fn idx(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            LinearKind::Gate => "gate_proj",
            LinearKind::Up => "up_proj",
            LinearKind::Down => "down_proj",
        }
    }
}

/// Full-precision MoE block: router + routed experts + shared experts.
#[derive(Clone, Debug)]
pub struct MoeBlock {
    /// `[n_experts, hidden]` router/gating weight.
    pub w_router: Matrix,
    pub experts: Vec<ExpertWeights>,
    /// Always-active shared experts.
    pub shared: Vec<ExpertWeights>,
    pub topk: usize,
}

impl MoeBlock {
    pub fn random(hidden: usize, inter: usize, n_experts: usize, n_shared: usize, topk: usize, rng: &mut Rng) -> MoeBlock {
        MoeBlock {
            w_router: Matrix::randn(n_experts, hidden, 1.0 / (hidden as f32).sqrt(), rng),
            experts: (0..n_experts).map(|_| ExpertWeights::random(hidden, inter, rng)).collect(),
            shared: (0..n_shared).map(|_| ExpertWeights::random(hidden, inter, rng)).collect(),
            topk,
        }
    }

    /// Total expert count including shared (allocation index space:
    /// routed experts first, then shared).
    pub fn total_experts(&self) -> usize {
        self.experts.len() + self.shared.len()
    }

    pub fn expert_at(&self, i: usize) -> &ExpertWeights {
        if i < self.experts.len() {
            &self.experts[i]
        } else {
            &self.shared[i - self.experts.len()]
        }
    }

    pub fn forward(&self, x: &Matrix) -> Matrix {
        self.forward_with_routing(x).0
    }

    pub fn forward_with_routing(&self, x: &Matrix) -> (Matrix, Routing) {
        let routing = route(x, &self.w_router, self.topk);
        let mut out = Matrix::zeros(x.rows, x.cols);
        for (e, (tokens, weights)) in routing.per_expert.iter().enumerate() {
            if tokens.is_empty() {
                continue;
            }
            let xe = x.gather_rows(tokens);
            let ye = self.experts[e].forward(&xe);
            out.scatter_add_rows(tokens, &ye, weights);
        }
        for s in &self.shared {
            let ys = s.forward(x);
            out.add_scaled(&ys, 1.0);
        }
        (out, routing)
    }
}

/// How to quantize weights given (optionally) calibration Hessians.
pub enum WeightQuantizer<'a> {
    /// Plain round-to-nearest.
    Rtn,
    /// GPTQ with per-(expert, linear) Hessians in the *rotated* basis when
    /// Hadamard is enabled. Indexed `[expert][linear]`, expert index covers
    /// routed then shared experts.
    Gptq { hessians: &'a [[Matrix; 3]], damp: f32 },
}

/// Per-block Hadamard rotation context: one sign vector per axis.
#[derive(Clone, Debug)]
pub struct HadamardCtx {
    /// signs along the hidden axis (gate/up inputs).
    pub signs_hidden: Vec<f32>,
    /// signs along the intermediate axis (down inputs).
    pub signs_inter: Vec<f32>,
}

impl HadamardCtx {
    pub fn random(hidden: usize, inter: usize, rng: &mut Rng) -> HadamardCtx {
        HadamardCtx {
            signs_hidden: random_signs(hidden, rng),
            signs_inter: random_signs(inter, rng),
        }
    }

    fn signs_for(&self, kind: LinearKind) -> &[f32] {
        match kind {
            LinearKind::Gate | LinearKind::Up => &self.signs_hidden,
            LinearKind::Down => &self.signs_inter,
        }
    }
}

/// A mixed-precision MoE block: per-linear-block schemes applied to weights
/// offline, activations fake-quantized at runtime.
pub struct QuantizedMoeBlock {
    /// fp32 router (attention/gating stay full precision in the paper).
    pub w_router: Matrix,
    /// Fake-quantized expert weights, routed then shared.
    pub qexperts: Vec<ExpertWeights>,
    /// Scheme per (expert, linear): `schemes[i][j]`, same index space.
    pub schemes: Vec<[QuantScheme; 3]>,
    pub n_routed: usize,
    pub topk: usize,
    pub hadamard: Option<HadamardCtx>,
}

impl QuantizedMoeBlock {
    /// Build from a full-precision block + per-linear-block scheme
    /// assignment (`schemes.len() == block.total_experts()`).
    pub fn build(
        block: &MoeBlock,
        schemes: &[[QuantScheme; 3]],
        quantizer: &WeightQuantizer<'_>,
        hadamard: Option<HadamardCtx>,
    ) -> Result<QuantizedMoeBlock> {
        assert_eq!(schemes.len(), block.total_experts());
        let mut qexperts = Vec::with_capacity(block.total_experts());
        for i in 0..block.total_experts() {
            let e = block.expert_at(i);
            let q = |w: &Matrix, kind: LinearKind| -> Result<Matrix> {
                let scheme = &schemes[i][kind.idx()];
                let w_in = match &hadamard {
                    Some(ctx) => rotate_weight(w, ctx.signs_for(kind)),
                    None => w.clone(),
                };
                match quantizer {
                    WeightQuantizer::Rtn => Ok(rtn_quantize(&w_in, scheme)),
                    WeightQuantizer::Gptq { hessians, damp } => {
                        gptq_quantize(&w_in, &hessians[i][kind.idx()], scheme, *damp)
                    }
                }
            };
            qexperts.push(ExpertWeights {
                gate: q(&e.gate, LinearKind::Gate)?,
                up: q(&e.up, LinearKind::Up)?,
                down: q(&e.down, LinearKind::Down)?,
            });
        }
        Ok(QuantizedMoeBlock {
            w_router: block.w_router.clone(),
            qexperts,
            schemes: schemes.to_vec(),
            n_routed: block.experts.len(),
            topk: block.topk,
            hadamard,
        })
    }

    /// One quantized linear: optional rotation → dynamic act quant → GEMM.
    fn quant_linear(&self, x: &Matrix, w_q: &Matrix, scheme: &QuantScheme, kind: LinearKind) -> Matrix {
        let x_in = match &self.hadamard {
            Some(ctx) => rotate_activations(x, ctx.signs_for(kind)),
            None => x.clone(),
        };
        let x_q = fake_quant_rows_act(&x_in, scheme.abits, scheme.agroup);
        matmul_nt(&x_q, w_q)
    }

    fn expert_forward(&self, i: usize, x: &Matrix) -> Matrix {
        let e = &self.qexperts[i];
        let s = &self.schemes[i];
        let g = self.quant_linear(x, &e.gate, &s[LinearKind::Gate.idx()], LinearKind::Gate);
        let u = self.quant_linear(x, &e.up, &s[LinearKind::Up.idx()], LinearKind::Up);
        let mut h = Matrix::zeros(g.rows, g.cols);
        for idx in 0..g.data.len() {
            h.data[idx] = silu(g.data[idx]) * u.data[idx];
        }
        self.quant_linear(&h, &e.down, &s[LinearKind::Down.idx()], LinearKind::Down)
    }

    pub fn forward(&self, x: &Matrix) -> Matrix {
        self.forward_with_routing(x).0
    }

    pub fn forward_with_routing(&self, x: &Matrix) -> (Matrix, Routing) {
        let routing = route(x, &self.w_router, self.topk);
        let mut out = Matrix::zeros(x.rows, x.cols);
        for (e, (tokens, weights)) in routing.per_expert.iter().enumerate() {
            if tokens.is_empty() {
                continue;
            }
            let xe = x.gather_rows(tokens);
            let ye = self.expert_forward(e, &xe);
            out.scatter_add_rows(tokens, &ye, weights);
        }
        for si in 0..self.qexperts.len() - self.n_routed {
            let ys = self.expert_forward(self.n_routed + si, x);
            out.add_scaled(&ys, 1.0);
        }
        (out, routing)
    }
}

/// Uniform scheme assignment helper (all linear blocks get `scheme`).
pub fn uniform_schemes(total_experts: usize, scheme: QuantScheme) -> Vec<[QuantScheme; 3]> {
    vec![[scheme, scheme, scheme]; total_experts]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_block(rng: &mut Rng) -> MoeBlock {
        MoeBlock::random(32, 16, 6, 1, 2, rng)
    }

    #[test]
    fn fp16_quant_block_matches_fp32() {
        let mut rng = Rng::new(90);
        let block = tiny_block(&mut rng);
        let x = Matrix::randn(20, 32, 1.0, &mut rng);
        let q = QuantizedMoeBlock::build(
            &block,
            &uniform_schemes(block.total_experts(), QuantScheme::FP16),
            &WeightQuantizer::Rtn,
            None,
        )
        .unwrap();
        let y = block.forward(&x);
        let yq = q.forward(&x);
        for (a, b) in y.data.iter().zip(&yq.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn quant_error_monotone_in_bits() {
        let mut rng = Rng::new(91);
        let block = tiny_block(&mut rng);
        let x = Matrix::randn(24, 32, 1.0, &mut rng);
        let y = block.forward(&x);
        let mut last = f64::INFINITY;
        for scheme in [QuantScheme::W2A16, QuantScheme::W4A16, QuantScheme::W8A16] {
            let q = QuantizedMoeBlock::build(
                &block,
                &uniform_schemes(block.total_experts(), scheme),
                &WeightQuantizer::Rtn,
                None,
            )
            .unwrap();
            let err = y.l2_distance(&q.forward(&x));
            assert!(err < last, "{scheme}: {err} !< {last}");
            assert!(err > 0.0);
            last = err;
        }
    }

    #[test]
    fn hadamard_forward_fp16_exact() {
        // with fp16 schemes the rotation must cancel exactly
        let mut rng = Rng::new(92);
        let block = tiny_block(&mut rng);
        let x = Matrix::randn(12, 32, 1.0, &mut rng);
        let ctx = HadamardCtx::random(32, 16, &mut rng);
        let q = QuantizedMoeBlock::build(
            &block,
            &uniform_schemes(block.total_experts(), QuantScheme::FP16),
            &WeightQuantizer::Rtn,
            Some(ctx),
        )
        .unwrap();
        let y = block.forward(&x);
        let yq = q.forward(&x);
        for (a, b) in y.data.iter().zip(&yq.data) {
            assert!((a - b).abs() < 2e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn mixed_schemes_respected_per_block() {
        // giving one expert's down_proj 2 bits must hurt more than giving it 8
        let mut rng = Rng::new(93);
        let block = tiny_block(&mut rng);
        let x = Matrix::randn(40, 32, 1.0, &mut rng);
        let y = block.forward(&x);
        let mut hi = uniform_schemes(block.total_experts(), QuantScheme::W8A16);
        hi[0][2] = QuantScheme::W8A16;
        let mut lo = hi.clone();
        lo[0][2] = QuantScheme::W2A16;
        let err_hi = {
            let q = QuantizedMoeBlock::build(&block, &hi, &WeightQuantizer::Rtn, None).unwrap();
            y.l2_distance(&q.forward(&x))
        };
        let err_lo = {
            let q = QuantizedMoeBlock::build(&block, &lo, &WeightQuantizer::Rtn, None).unwrap();
            y.l2_distance(&q.forward(&x))
        };
        assert!(err_lo > err_hi, "{err_lo} !> {err_hi}");
    }

    #[test]
    fn shared_experts_always_contribute() {
        let mut rng = Rng::new(94);
        let mut block = tiny_block(&mut rng);
        let x = Matrix::randn(10, 32, 1.0, &mut rng);
        let y_with = block.forward(&x);
        block.shared.clear();
        let y_without = block.forward(&x);
        assert!(y_with.l2_distance(&y_without) > 1e-3);
    }
}
