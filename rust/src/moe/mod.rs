//! Native MoE transformer substrate.
//!
//! This is the f32 reference implementation used for calibration,
//! quantization (GPTQ needs layer inputs), sensitivity measurement (Δ of
//! Eq. 6) and perplexity/probe evaluation. The serving hot path runs the
//! same math through AOT-compiled PJRT executables (`crate::runtime`); this
//! module is the ground truth those executables are checked against.

pub mod block;
pub mod config;
pub mod expert;
pub mod lm;
pub mod router;

pub use block::{LinearKind, MoeBlock, QuantizedMoeBlock};
pub use config::ModelConfig;
pub use expert::ExpertWeights;
pub use lm::{MoeLm, StepSeq};
pub use router::{route, Routing};
