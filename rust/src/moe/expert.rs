//! Expert FFN weights and forward (the paper's Eq. 1:
//! `down( silu(gate(x)) ⊙ up(x) )`).

use crate::tensor::matrix::matmul_nt;
use crate::tensor::ops::silu;
use crate::tensor::Matrix;
use crate::util::Rng;

/// SwiGLU expert: three linear blocks, weights stored `[out, in]` row-major
/// (`y = x·Wᵀ`).
#[derive(Clone, Debug)]
pub struct ExpertWeights {
    /// `[inter, hidden]`
    pub gate: Matrix,
    /// `[inter, hidden]`
    pub up: Matrix,
    /// `[hidden, inter]`
    pub down: Matrix,
}

impl ExpertWeights {
    pub fn random(hidden: usize, inter: usize, rng: &mut Rng) -> ExpertWeights {
        let std_in = 1.0 / (hidden as f32).sqrt();
        let std_out = 1.0 / (inter as f32).sqrt();
        ExpertWeights {
            gate: Matrix::randn(inter, hidden, std_in, rng),
            up: Matrix::randn(inter, hidden, std_in, rng),
            down: Matrix::randn(hidden, inter, std_out, rng),
        }
    }

    /// Forward `[t, hidden] → [t, hidden]`.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        let g = matmul_nt(x, &self.gate);
        let u = matmul_nt(x, &self.up);
        let mut h = Matrix::zeros(g.rows, g.cols);
        for i in 0..g.data.len() {
            h.data[i] = silu(g.data[i]) * u.data[i];
        }
        matmul_nt(&h, &self.down)
    }

    /// The intermediate `h = silu(gate(x)) ⊙ up(x)` — the input of the
    /// down-proj linear block (needed for GPTQ Hessians and down-proj
    /// sensitivity).
    pub fn intermediate(&self, x: &Matrix) -> Matrix {
        let g = matmul_nt(x, &self.gate);
        let u = matmul_nt(x, &self.up);
        let mut h = Matrix::zeros(g.rows, g.cols);
        for i in 0..g.data.len() {
            h.data[i] = silu(g.data[i]) * u.data[i];
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(80);
        let e = ExpertWeights::random(16, 32, &mut rng);
        let x = Matrix::randn(5, 16, 1.0, &mut rng);
        let y = e.forward(&x);
        assert_eq!((y.rows, y.cols), (5, 16));
    }

    #[test]
    fn forward_composes_from_intermediate() {
        let mut rng = Rng::new(81);
        let e = ExpertWeights::random(8, 16, &mut rng);
        let x = Matrix::randn(3, 8, 1.0, &mut rng);
        let h = e.intermediate(&x);
        let y = matmul_nt(&h, &e.down);
        let y2 = e.forward(&x);
        for (a, b) in y.data.iter().zip(&y2.data) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn zero_input_zero_output() {
        let mut rng = Rng::new(82);
        let e = ExpertWeights::random(8, 16, &mut rng);
        let x = Matrix::zeros(2, 8);
        let y = e.forward(&x);
        assert!(y.data.iter().all(|&v| v == 0.0));
    }
}
