//! Model architecture configs.
//!
//! The four mini models mirror the expert topology of the paper's Tab. 2
//! (experts, shared experts, top-k, DeepSeek's dense first layer) at
//! laptop-trainable dimensions. Hidden/intermediate sizes are powers of two
//! so Hadamard incoherence processing applies on every axis.

use anyhow::{bail, Result};

use crate::ser::Json;

/// Architecture of a mini MoE language model.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    /// Routed experts per MoE block.
    pub n_experts: usize,
    /// Always-active shared experts (Qwen/DeepSeek style).
    pub n_shared: usize,
    /// Routed experts activated per token.
    pub topk: usize,
    /// Expert FFN intermediate size.
    pub inter: usize,
    /// DeepSeek-V2 style: first layer uses a dense MLP instead of MoE.
    pub dense_first: bool,
    /// Training/eval sequence length.
    pub seq_len: usize,
}

impl ModelConfig {
    /// Mixtral-8×7B analogue: 8 experts, top-2, no shared experts.
    pub fn mixtral_mini() -> ModelConfig {
        ModelConfig {
            name: "mixtral-mini".into(),
            vocab: 512,
            hidden: 128,
            layers: 4,
            heads: 4,
            n_experts: 8,
            n_shared: 0,
            topk: 2,
            inter: 256,
            dense_first: false,
            seq_len: 128,
        }
    }

    /// Qwen1.5-MoE analogue: 60 routed + 4 shared, top-4.
    pub fn qwen15_mini() -> ModelConfig {
        ModelConfig {
            name: "qwen15-mini".into(),
            vocab: 512,
            hidden: 128,
            layers: 4,
            heads: 4,
            n_experts: 60,
            n_shared: 4,
            topk: 4,
            inter: 64,
            dense_first: false,
            seq_len: 128,
        }
    }

    /// Qwen2-MoE analogue: 64 routed + 8 shared, top-8.
    pub fn qwen2_mini() -> ModelConfig {
        ModelConfig {
            name: "qwen2-mini".into(),
            vocab: 512,
            hidden: 128,
            layers: 4,
            heads: 4,
            n_experts: 64,
            n_shared: 8,
            topk: 8,
            inter: 64,
            dense_first: false,
            seq_len: 128,
        }
    }

    /// DeepSeek-V2-Lite analogue: 64 routed + 2 shared, top-6, dense layer 0.
    pub fn dsv2_mini() -> ModelConfig {
        ModelConfig {
            name: "dsv2-mini".into(),
            vocab: 512,
            hidden: 128,
            layers: 4,
            heads: 4,
            n_experts: 64,
            n_shared: 2,
            topk: 6,
            inter: 64,
            dense_first: true,
            seq_len: 128,
        }
    }

    /// Deterministic CI fixture: serving-shape expert dims (hidden 128,
    /// inter 64 — exactly what the AOT export ships) but tiny everywhere
    /// else, so `make mini-model` writes a loadable checkpoint in
    /// milliseconds and CI can exercise `make models`-gated paths without
    /// training. Not part of [`all_minis`](Self::all_minis) — the
    /// experiment tables stay four-model.
    pub fn ci_mini() -> ModelConfig {
        ModelConfig {
            name: "ci-mini".into(),
            vocab: 64,
            hidden: 128,
            layers: 2,
            heads: 4,
            n_experts: 4,
            n_shared: 1,
            topk: 2,
            inter: 64,
            dense_first: false,
            seq_len: 32,
        }
    }

    /// All four evaluation models (Tab. 1 / Tab. 2 order).
    pub fn all_minis() -> Vec<ModelConfig> {
        vec![
            ModelConfig::dsv2_mini(),
            ModelConfig::qwen15_mini(),
            ModelConfig::qwen2_mini(),
            ModelConfig::mixtral_mini(),
        ]
    }

    pub fn by_name(name: &str) -> Result<ModelConfig> {
        for c in ModelConfig::all_minis().into_iter().chain([ModelConfig::ci_mini()]) {
            if c.name == name {
                return Ok(c);
            }
        }
        bail!(
            "unknown model '{name}' \
             (known: dsv2-mini, qwen15-mini, qwen2-mini, mixtral-mini, ci-mini)"
        )
    }

    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Which layers carry a MoE block.
    pub fn moe_layers(&self) -> Vec<usize> {
        (0..self.layers)
            .filter(|&l| !(self.dense_first && l == 0))
            .collect()
    }

    /// Linear blocks per expert (gate/up/down), the paper's `N = 3`.
    pub const LINEARS_PER_EXPERT: usize = 3;

    /// Total parameter count (for reporting).
    pub fn param_count(&self) -> usize {
        let emb = self.vocab * self.hidden * 2; // embed + head
        let attn = self.layers * (4 * self.hidden * self.hidden + 2 * self.hidden);
        let expert = 3 * self.inter * self.hidden;
        let moe: usize = self
            .moe_layers()
            .iter()
            .map(|_| (self.n_experts + self.n_shared) * expert + self.n_experts * self.hidden)
            .sum();
        let dense: usize = if self.dense_first {
            // dense MLP sized to match total expert compute per token
            3 * self.inter * self.topk * self.hidden
        } else {
            0
        };
        emb + attn + moe + dense
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("vocab", Json::num(self.vocab as f64)),
            ("hidden", Json::num(self.hidden as f64)),
            ("layers", Json::num(self.layers as f64)),
            ("heads", Json::num(self.heads as f64)),
            ("n_experts", Json::num(self.n_experts as f64)),
            ("n_shared", Json::num(self.n_shared as f64)),
            ("topk", Json::num(self.topk as f64)),
            ("inter", Json::num(self.inter as f64)),
            ("dense_first", Json::Bool(self.dense_first)),
            ("seq_len", Json::num(self.seq_len as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: v.req_str("name")?.to_string(),
            vocab: v.req_usize("vocab")?,
            hidden: v.req_usize("hidden")?,
            layers: v.req_usize("layers")?,
            heads: v.req_usize("heads")?,
            n_experts: v.req_usize("n_experts")?,
            n_shared: v.req_usize("n_shared")?,
            topk: v.req_usize("topk")?,
            inter: v.req_usize("inter")?,
            dense_first: v.get("dense_first").and_then(Json::as_bool).unwrap_or(false),
            seq_len: v.req_usize("seq_len")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_topologies_match_paper_table2() {
        let m = ModelConfig::mixtral_mini();
        assert_eq!((m.n_experts, m.n_shared, m.topk), (8, 0, 2));
        let q1 = ModelConfig::qwen15_mini();
        assert_eq!((q1.n_experts, q1.n_shared, q1.topk), (60, 4, 4));
        let q2 = ModelConfig::qwen2_mini();
        assert_eq!((q2.n_experts, q2.n_shared, q2.topk), (64, 8, 8));
        let ds = ModelConfig::dsv2_mini();
        assert_eq!((ds.n_experts, ds.n_shared, ds.topk), (64, 2, 6));
        assert!(ds.dense_first);
    }

    #[test]
    fn dense_first_drops_layer_zero() {
        let ds = ModelConfig::dsv2_mini();
        assert_eq!(ds.moe_layers(), vec![1, 2, 3]);
        let m = ModelConfig::mixtral_mini();
        assert_eq!(m.moe_layers(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn json_roundtrip() {
        for c in ModelConfig::all_minis() {
            let j = c.to_json();
            let c2 = ModelConfig::from_json(&j).unwrap();
            assert_eq!(c, c2);
        }
    }

    #[test]
    fn by_name_errors_on_unknown() {
        assert!(ModelConfig::by_name("gpt-5").is_err());
        assert!(ModelConfig::by_name("dsv2-mini").is_ok());
        assert!(ModelConfig::by_name("ci-mini").is_ok());
    }

    #[test]
    fn ci_mini_is_serving_shaped_but_not_an_eval_model() {
        let c = ModelConfig::ci_mini();
        assert_eq!((c.hidden, c.inter), (128, 64), "must match the AOT export shapes");
        assert!(ModelConfig::all_minis().iter().all(|m| m.name != c.name));
        assert!(c.param_count() < ModelConfig::qwen15_mini().param_count() / 4);
    }

    #[test]
    fn power_of_two_dims_for_hadamard() {
        for c in ModelConfig::all_minis() {
            assert!(c.hidden.is_power_of_two());
            assert!(c.inter.is_power_of_two());
        }
    }
}
