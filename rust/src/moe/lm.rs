//! The mini MoE transformer LM: the accuracy-evaluation substrate.
//!
//! Architecture (must stay byte-compatible with the JAX trainer in
//! `python/compile/moe_lm.py`, which writes the MXT weight files):
//!
//! ```text
//! embed [vocab, hidden]
//! per layer l:
//!   ln1 [hidden] → MHA (wq,wk,wv,wo [hidden,hidden], RoPE θ=10000, causal) → +res
//!   ln2 [hidden] → MoE block (or dense SwiGLU at layer 0 when dense_first) → +res
//! ln_f [hidden] → head [vocab, hidden]
//! ```

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::ser::mxt::MxtFile;
use crate::serve::kvcache::SeqKv;
use crate::tensor::matrix::matmul_nt;
use crate::tensor::ops::rmsnorm;
use crate::tensor::{softmax_rows, Matrix};
use crate::util::Rng;

use super::block::{MoeBlock, QuantizedMoeBlock};
use super::config::ModelConfig;
use super::expert::ExpertWeights;
use super::router::Routing;

/// One transformer layer's weights.
#[derive(Clone, Debug)]
pub struct Layer {
    pub ln1: Vec<f32>,
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub wo: Matrix,
    pub ln2: Vec<f32>,
    pub ffn: Ffn,
}

/// A layer's feed-forward: MoE or dense (DeepSeek's first layer).
#[derive(Clone, Debug)]
pub enum Ffn {
    Moe(MoeBlock),
    Dense(ExpertWeights),
}

/// The full model.
pub struct MoeLm {
    pub cfg: ModelConfig,
    pub embed: Matrix,
    pub layers: Vec<Layer>,
    pub ln_f: Vec<f32>,
    pub head: Matrix,
}

/// One sequence's contribution to an incremental step batch: the new
/// tokens to process plus its KV cache (whose length is the absolute
/// position of `tokens[0]`). A decode row is a 1-token step; a prefill
/// chunk is a many-token step — the scheduler mixes both freely.
pub struct StepSeq<'a> {
    pub tokens: &'a [u32],
    pub cache: &'a mut SeqKv,
}

/// Captured state at one MoE layer during a forward pass.
pub struct MoeCapture {
    /// Layer index in the transformer.
    pub layer: usize,
    /// Input of the MoE block (after ln2) — gate/up linear-block input.
    pub moe_input: Matrix,
    pub routing: Routing,
}

impl MoeLm {
    pub fn random(cfg: &ModelConfig, rng: &mut Rng) -> MoeLm {
        let h = cfg.hidden;
        let std = 1.0 / (h as f32).sqrt();
        let layers = (0..cfg.layers)
            .map(|l| Layer {
                ln1: vec![1.0; h],
                wq: Matrix::randn(h, h, std, rng),
                wk: Matrix::randn(h, h, std, rng),
                wv: Matrix::randn(h, h, std, rng),
                wo: Matrix::randn(h, h, std, rng),
                ln2: vec![1.0; h],
                ffn: if cfg.dense_first && l == 0 {
                    Ffn::Dense(ExpertWeights::random(h, cfg.inter * cfg.topk, rng))
                } else {
                    Ffn::Moe(MoeBlock::random(h, cfg.inter, cfg.n_experts, cfg.n_shared, cfg.topk, rng))
                },
            })
            .collect();
        MoeLm {
            cfg: cfg.clone(),
            embed: Matrix::randn(cfg.vocab, h, 1.0, rng),
            layers,
            ln_f: vec![1.0; h],
            head: Matrix::randn(cfg.vocab, h, std, rng),
        }
    }

    /// Load from an MXT weight file written by `python/compile/moe_lm.py`.
    pub fn load_mxt(cfg: &ModelConfig, f: &MxtFile) -> Result<MoeLm> {
        let mat = |name: &str, rows: usize, cols: usize| -> Result<Matrix> {
            let (shape, vals) = f.f32(name)?;
            if shape != vec![rows, cols] {
                bail!("{name}: shape {shape:?}, expected [{rows}, {cols}]");
            }
            Ok(Matrix::from_vec(rows, cols, vals))
        };
        let vec1 = |name: &str, n: usize| -> Result<Vec<f32>> {
            let (shape, vals) = f.f32(name)?;
            if shape != vec![n] {
                bail!("{name}: shape {shape:?}, expected [{n}]");
            }
            Ok(vals)
        };
        let h = cfg.hidden;
        let mut layers = Vec::with_capacity(cfg.layers);
        for l in 0..cfg.layers {
            let p = |s: &str| format!("layers.{l}.{s}");
            let ffn = if cfg.dense_first && l == 0 {
                Ffn::Dense(ExpertWeights {
                    gate: mat(&p("dense.gate"), cfg.inter * cfg.topk, h)?,
                    up: mat(&p("dense.up"), cfg.inter * cfg.topk, h)?,
                    down: mat(&p("dense.down"), h, cfg.inter * cfg.topk)?,
                })
            } else {
                let mut experts = Vec::with_capacity(cfg.n_experts);
                for e in 0..cfg.n_experts {
                    experts.push(ExpertWeights {
                        gate: mat(&p(&format!("expert.{e}.gate")), cfg.inter, h)?,
                        up: mat(&p(&format!("expert.{e}.up")), cfg.inter, h)?,
                        down: mat(&p(&format!("expert.{e}.down")), h, cfg.inter)?,
                    });
                }
                let mut shared = Vec::with_capacity(cfg.n_shared);
                for s in 0..cfg.n_shared {
                    shared.push(ExpertWeights {
                        gate: mat(&p(&format!("shared.{s}.gate")), cfg.inter, h)?,
                        up: mat(&p(&format!("shared.{s}.up")), cfg.inter, h)?,
                        down: mat(&p(&format!("shared.{s}.down")), h, cfg.inter)?,
                    });
                }
                Ffn::Moe(MoeBlock {
                    w_router: mat(&p("router"), cfg.n_experts, h)?,
                    experts,
                    shared,
                    topk: cfg.topk,
                })
            };
            layers.push(Layer {
                ln1: vec1(&p("ln1"), h)?,
                wq: mat(&p("wq"), h, h)?,
                wk: mat(&p("wk"), h, h)?,
                wv: mat(&p("wv"), h, h)?,
                wo: mat(&p("wo"), h, h)?,
                ln2: vec1(&p("ln2"), h)?,
                ffn,
            });
        }
        Ok(MoeLm {
            cfg: cfg.clone(),
            embed: mat("embed", cfg.vocab, h).context("embed")?,
            layers,
            ln_f: vec1("ln_f", h)?,
            head: mat("head", cfg.vocab, h)?,
        })
    }

    /// MoE blocks by layer index.
    pub fn moe_blocks(&self) -> Vec<(usize, &MoeBlock)> {
        self.layers
            .iter()
            .enumerate()
            .filter_map(|(l, layer)| match &layer.ffn {
                Ffn::Moe(b) => Some((l, b)),
                Ffn::Dense(_) => None,
            })
            .collect()
    }

    /// Forward over one token sequence; returns logits `[T, vocab]`.
    pub fn forward(&self, tokens: &[u32]) -> Matrix {
        self.forward_inner(tokens, None, &HashMap::new()).0
    }

    /// Forward that captures every MoE block's input + routing
    /// (calibration path).
    pub fn forward_capture(&self, tokens: &[u32]) -> (Matrix, Vec<MoeCapture>) {
        let mut caps = Vec::new();
        let logits = self.forward_inner(tokens, Some(&mut caps), &HashMap::new()).0;
        (logits, caps)
    }

    /// Forward with some MoE layers replaced by quantized blocks
    /// (quantized-model evaluation path).
    pub fn forward_quantized(&self, tokens: &[u32], replacements: &HashMap<usize, &QuantizedMoeBlock>) -> Matrix {
        self.forward_inner(tokens, None, replacements).0
    }

    fn forward_inner(
        &self,
        tokens: &[u32],
        mut capture: Option<&mut Vec<MoeCapture>>,
        replacements: &HashMap<usize, &QuantizedMoeBlock>,
    ) -> (Matrix, ()) {
        let t = tokens.len();
        let h = self.cfg.hidden;
        let mut x = Matrix::zeros(t, h);
        for (i, &tok) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.embed.row(tok as usize));
        }
        for (l, layer) in self.layers.iter().enumerate() {
            // --- attention ---
            let xn = rmsnorm(&x, &layer.ln1, 1e-6);
            let att = self.attention(&xn, layer);
            x.add_scaled(&att, 1.0);
            // --- ffn ---
            let xn = rmsnorm(&x, &layer.ln2, 1e-6);
            let y = match (&layer.ffn, replacements.get(&l)) {
                (_, Some(q)) => {
                    let (y, routing) = q.forward_with_routing(&xn);
                    if let Some(caps) = capture.as_deref_mut() {
                        caps.push(MoeCapture { layer: l, moe_input: xn.clone(), routing });
                    }
                    y
                }
                (Ffn::Moe(b), None) => {
                    let (y, routing) = b.forward_with_routing(&xn);
                    if let Some(caps) = capture.as_deref_mut() {
                        caps.push(MoeCapture { layer: l, moe_input: xn.clone(), routing });
                    }
                    y
                }
                (Ffn::Dense(d), None) => d.forward(&xn),
            };
            x.add_scaled(&y, 1.0);
        }
        let xf = rmsnorm(&x, &self.ln_f, 1e-6);
        (matmul_nt(&xf, &self.head), ())
    }

    /// Batched forward with a custom MoE executor: attention/norm run
    /// natively per sequence, while all sequences' MoE tokens are
    /// *concatenated* per layer and handed to `moe_exec(layer_idx, block,
    /// concat_rows)` — the hook the serving engine uses to dispatch expert
    /// compute to PJRT executables with cross-request batching.
    pub fn forward_batch_with_moe<F>(&self, batch: &[&[u32]], mut moe_exec: F) -> Vec<Matrix>
    where
        F: FnMut(usize, &MoeBlock, &Matrix) -> Matrix,
    {
        let h = self.cfg.hidden;
        let mut xs: Vec<Matrix> = batch
            .iter()
            .map(|tokens| {
                let mut x = Matrix::zeros(tokens.len(), h);
                for (i, &tok) in tokens.iter().enumerate() {
                    x.row_mut(i).copy_from_slice(self.embed.row(tok as usize));
                }
                x
            })
            .collect();
        for (l, layer) in self.layers.iter().enumerate() {
            for x in xs.iter_mut() {
                let xn = rmsnorm(x, &layer.ln1, 1e-6);
                let att = self.attention(&xn, layer);
                x.add_scaled(&att, 1.0);
            }
            match &layer.ffn {
                Ffn::Dense(d) => {
                    for x in xs.iter_mut() {
                        let xn = rmsnorm(x, &layer.ln2, 1e-6);
                        x.add_scaled(&d.forward(&xn), 1.0);
                    }
                }
                Ffn::Moe(block) => {
                    // concatenate all sequences' tokens for one dispatch
                    let total: usize = xs.iter().map(|x| x.rows).sum();
                    let mut cat = Matrix::zeros(total, h);
                    let mut off = 0;
                    for x in &xs {
                        let xn = rmsnorm(x, &layer.ln2, 1e-6);
                        cat.data[off * h..(off + x.rows) * h].copy_from_slice(&xn.data);
                        off += x.rows;
                    }
                    let y = moe_exec(l, block, &cat);
                    assert_eq!((y.rows, y.cols), (total, h));
                    let mut off = 0;
                    for x in xs.iter_mut() {
                        let rows = x.rows;
                        for r in 0..rows {
                            for c in 0..h {
                                *x.at_mut(r, c) += y.at(off + r, c);
                            }
                        }
                        off += rows;
                    }
                }
            }
        }
        xs.into_iter()
            .map(|x| {
                let xf = rmsnorm(&x, &self.ln_f, 1e-6);
                matmul_nt(&xf, &self.head)
            })
            .collect()
    }

    /// Incremental forward (DESIGN.md §Decode-Loop): process `tokens` at
    /// absolute positions `cache.len()..`, appending each layer's K/V to
    /// the cache and attending over the cached prefix. Returns logits for
    /// the new positions only (`[tokens.len(), vocab]`). Every op on this
    /// path is row-independent and runs in the same accumulation order as
    /// the whole-sequence forward, so prefill-then-decode logits are
    /// bit-identical to [`forward`](Self::forward)/[`forward_capture`](Self::forward_capture)
    /// of the full token sequence.
    pub fn forward_step(&self, tokens: &[u32], cache: &mut SeqKv) -> Matrix {
        self.forward_step_quantized(tokens, cache, &HashMap::new())
    }

    /// [`forward_step`](Self::forward_step) with some MoE layers replaced
    /// by quantized blocks — the decode twin of
    /// [`forward_quantized`](Self::forward_quantized), bit-identical to it
    /// on the same sequence for any replacement map.
    pub fn forward_step_quantized(
        &self,
        tokens: &[u32],
        cache: &mut SeqKv,
        replacements: &HashMap<usize, &QuantizedMoeBlock>,
    ) -> Matrix {
        let mut seqs = [StepSeq { tokens, cache }];
        let mut out = self.forward_step_batch_with_moe(&mut seqs, |l, block, x| {
            match replacements.get(&l) {
                Some(q) => q.forward(x),
                None => block.forward(x),
            }
        });
        out.pop().unwrap()
    }

    /// Batched incremental forward with a custom MoE executor — the decode
    /// twin of [`forward_batch_with_moe`](Self::forward_batch_with_moe).
    /// Attention/norm run natively per sequence against each sequence's KV
    /// cache, while all sequences' new rows are *concatenated* per MoE
    /// layer and handed to `moe_exec` — one mixed prefill/decode step
    /// dispatches a single expert batch per layer, which is what lets the
    /// decode scheduler fill tiles across sequences. Caches are appended
    /// and committed before returning.
    pub fn forward_step_batch_with_moe<F>(&self, seqs: &mut [StepSeq<'_>], mut moe_exec: F) -> Vec<Matrix>
    where
        F: FnMut(usize, &MoeBlock, &Matrix) -> Matrix,
    {
        let h = self.cfg.hidden;
        for s in seqs.iter() {
            assert!(!s.tokens.is_empty(), "empty step");
            assert_eq!(s.cache.n_layers(), self.layers.len(), "cache/model layer mismatch");
        }
        let mut xs: Vec<Matrix> = seqs
            .iter()
            .map(|s| {
                let mut x = Matrix::zeros(s.tokens.len(), h);
                for (i, &tok) in s.tokens.iter().enumerate() {
                    x.row_mut(i).copy_from_slice(self.embed.row(tok as usize));
                }
                x
            })
            .collect();
        for (l, layer) in self.layers.iter().enumerate() {
            // --- attention over each sequence's cached prefix ---
            for (x, s) in xs.iter_mut().zip(seqs.iter_mut()) {
                let xn = rmsnorm(x, &layer.ln1, 1e-6);
                let att = self.attention_step(&xn, layer, l, s.cache);
                x.add_scaled(&att, 1.0);
            }
            // --- ffn: concatenate all sequences' new rows per dispatch ---
            match &layer.ffn {
                Ffn::Dense(d) => {
                    for x in xs.iter_mut() {
                        let xn = rmsnorm(x, &layer.ln2, 1e-6);
                        x.add_scaled(&d.forward(&xn), 1.0);
                    }
                }
                Ffn::Moe(block) => {
                    let total: usize = xs.iter().map(|x| x.rows).sum();
                    let mut cat = Matrix::zeros(total, h);
                    let mut off = 0;
                    for x in &xs {
                        let xn = rmsnorm(x, &layer.ln2, 1e-6);
                        cat.data[off * h..(off + x.rows) * h].copy_from_slice(&xn.data);
                        off += x.rows;
                    }
                    let y = moe_exec(l, block, &cat);
                    assert_eq!((y.rows, y.cols), (total, h));
                    let mut off = 0;
                    for x in xs.iter_mut() {
                        let rows = x.rows;
                        for r in 0..rows {
                            for c in 0..h {
                                *x.at_mut(r, c) += y.at(off + r, c);
                            }
                        }
                        off += rows;
                    }
                }
            }
        }
        // commit the appended positions only after every layer ran, so a
        // mid-step panic never leaves the cache length torn across layers
        for s in seqs.iter_mut() {
            s.cache.advance(s.tokens.len());
        }
        xs.into_iter()
            .map(|x| {
                let xf = rmsnorm(&x, &self.ln_f, 1e-6);
                matmul_nt(&xf, &self.head)
            })
            .collect()
    }

    /// Causal attention of one step's new rows over the cached prefix.
    /// Appends this layer's post-RoPE K (and raw V) rows to the cache, then
    /// reproduces [`attention`](Self::attention)'s arithmetic exactly —
    /// same score order, same softmax shape (a `-inf` tail adds exact
    /// zeros), same accumulation order — so step outputs are bit-identical
    /// to the whole-sequence rows. The prefix is gathered through the
    /// cache's page table in position order (contiguous page runs), which
    /// changes where rows live but not a single arithmetic operation:
    /// fp32-mode paging stays bit-identical to the contiguous cache.
    fn attention_step(&self, xn: &Matrix, layer: &Layer, l: usize, cache: &mut SeqKv) -> Matrix {
        let s = xn.rows;
        let h = self.cfg.hidden;
        let heads = self.cfg.heads;
        let hd = self.cfg.head_dim();
        let p0 = cache.len();
        let mut q = matmul_nt(xn, &layer.wq);
        let mut k = matmul_nt(xn, &layer.wk);
        let v = matmul_nt(xn, &layer.wv);
        apply_rope_at(&mut q, heads, hd, p0);
        apply_rope_at(&mut k, heads, hd, p0);
        cache.append(l, &k, &v);
        let scale = 1.0 / (hd as f32).sqrt();
        let mut ctx = Matrix::zeros(s, h);
        let mut scores = Vec::new();
        for head in 0..heads {
            let off = head * hd;
            for i in 0..s {
                let t1 = p0 + i; // absolute position of this new row
                scores.clear();
                // gather K through the page table in position order, one
                // contiguous page run at a time — the same rows in the
                // same order as a per-position walk, so the scores are
                // bit-identical to the contiguous-cache gather
                let mut t2 = 0;
                while t2 <= t1 {
                    let (krows, nrun) = cache.key_run(l, t2, t1 + 1);
                    for j in 0..nrun {
                        let krow = &krows[j * h..(j + 1) * h];
                        let mut sum = 0.0f32;
                        for d in 0..hd {
                            sum += q.at(i, off + d) * krow[off + d];
                        }
                        scores.push(sum * scale);
                    }
                    t2 += nrun;
                }
                // softmax over the causal prefix — bit-identical to
                // `softmax_rows` over the full row, whose -inf tail
                // contributes exact zeros to max and sum
                let m = scores.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let mut z = 0.0f32;
                for v in scores.iter_mut() {
                    *v = (*v - m).exp();
                    z += *v;
                }
                let inv = 1.0 / z;
                for v in scores.iter_mut() {
                    *v *= inv;
                }
                let mut t2 = 0;
                while t2 <= t1 {
                    let (vrows, nrun) = cache.value_run(l, t2, t1 + 1);
                    for j in 0..nrun {
                        let a = scores[t2 + j];
                        if a == 0.0 {
                            continue;
                        }
                        let vrow = &vrows[j * h..(j + 1) * h];
                        for d in 0..hd {
                            *ctx.at_mut(i, off + d) += a * vrow[off + d];
                        }
                    }
                    t2 += nrun;
                }
            }
        }
        matmul_nt(&ctx, &layer.wo)
    }

    /// Causal multi-head attention with RoPE.
    fn attention(&self, xn: &Matrix, layer: &Layer) -> Matrix {
        let t = xn.rows;
        let h = self.cfg.hidden;
        let heads = self.cfg.heads;
        let hd = self.cfg.head_dim();
        let mut q = matmul_nt(xn, &layer.wq);
        let mut k = matmul_nt(xn, &layer.wk);
        let v = matmul_nt(xn, &layer.wv);
        apply_rope(&mut q, heads, hd);
        apply_rope(&mut k, heads, hd);
        let scale = 1.0 / (hd as f32).sqrt();
        let mut ctx = Matrix::zeros(t, h);
        for head in 0..heads {
            let off = head * hd;
            // scores[t1, t2] over the causal prefix
            let mut scores = Matrix::zeros(t, t);
            for t1 in 0..t {
                for t2 in 0..=t1 {
                    let mut s = 0.0;
                    for d in 0..hd {
                        s += q.at(t1, off + d) * k.at(t2, off + d);
                    }
                    *scores.at_mut(t1, t2) = s * scale;
                }
                for t2 in t1 + 1..t {
                    *scores.at_mut(t1, t2) = f32::NEG_INFINITY;
                }
            }
            softmax_rows(&mut scores);
            for t1 in 0..t {
                for t2 in 0..=t1 {
                    let a = scores.at(t1, t2);
                    if a == 0.0 {
                        continue;
                    }
                    for d in 0..hd {
                        *ctx.at_mut(t1, off + d) += a * v.at(t2, off + d);
                    }
                }
            }
        }
        matmul_nt(&ctx, &layer.wo)
    }
}

/// Rotary position embedding, θ = 10000, applied per head to pairs
/// `(2i, 2i+1)` — identical to `python/compile/moe_lm.py::rope`.
pub fn apply_rope(x: &mut Matrix, heads: usize, head_dim: usize) {
    apply_rope_at(x, heads, head_dim, 0)
}

/// [`apply_rope`] with row `i` rotated for *absolute* position
/// `start_pos + i` — the decode path's entry point, where a step's rows
/// sit at the end of an already-cached prefix. `apply_rope` is the
/// `start_pos = 0` case, so the angle arithmetic is shared (and therefore
/// bit-identical) between the whole-sequence and incremental paths.
pub fn apply_rope_at(x: &mut Matrix, heads: usize, head_dim: usize, start_pos: usize) {
    let t = x.rows;
    for i in 0..t {
        let pos = start_pos + i;
        let row = x.row_mut(i);
        for head in 0..heads {
            let off = head * head_dim;
            for j in 0..head_dim / 2 {
                let theta = (pos as f32) / 10000f32.powf(2.0 * j as f32 / head_dim as f32);
                let (sin, cos) = theta.sin_cos();
                let a = row[off + 2 * j];
                let b = row[off + 2 * j + 1];
                row[off + 2 * j] = a * cos - b * sin;
                row[off + 2 * j + 1] = a * sin + b * cos;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            vocab: 32,
            hidden: 16,
            layers: 2,
            heads: 2,
            n_experts: 4,
            n_shared: 1,
            topk: 2,
            inter: 8,
            dense_first: false,
            seq_len: 12,
        }
    }

    #[test]
    fn forward_shapes_and_finite() {
        let mut rng = Rng::new(100);
        let lm = MoeLm::random(&tiny_cfg(), &mut rng);
        let tokens: Vec<u32> = (0..10).map(|_| rng.below(32) as u32).collect();
        let logits = lm.forward(&tokens);
        assert_eq!((logits.rows, logits.cols), (10, 32));
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality_prefix_invariance() {
        // logits at position i must not depend on tokens after i
        let mut rng = Rng::new(101);
        let lm = MoeLm::random(&tiny_cfg(), &mut rng);
        let t1: Vec<u32> = (0..8).map(|_| rng.below(32) as u32).collect();
        let mut t2 = t1.clone();
        t2[7] = (t2[7] + 1) % 32;
        let l1 = lm.forward(&t1);
        let l2 = lm.forward(&t2);
        for pos in 0..7 {
            for c in 0..32 {
                assert!(
                    (l1.at(pos, c) - l2.at(pos, c)).abs() < 1e-4,
                    "position {pos} leaked future token"
                );
            }
        }
    }

    #[test]
    fn capture_collects_all_moe_layers() {
        let mut rng = Rng::new(102);
        let lm = MoeLm::random(&tiny_cfg(), &mut rng);
        let tokens: Vec<u32> = (0..6).map(|_| rng.below(32) as u32).collect();
        let (_, caps) = lm.forward_capture(&tokens);
        assert_eq!(caps.len(), 2);
        assert_eq!(caps[0].moe_input.rows, 6);
        let counts = caps[0].routing.activation_counts();
        assert_eq!(counts.iter().sum::<usize>(), 6 * 2);
    }

    #[test]
    fn dense_first_layer_has_no_moe() {
        let mut cfg = tiny_cfg();
        cfg.dense_first = true;
        let mut rng = Rng::new(103);
        let lm = MoeLm::random(&cfg, &mut rng);
        assert_eq!(lm.moe_blocks().len(), 1);
        let (_, caps) = lm.forward_capture(&[1, 2, 3]);
        assert_eq!(caps.len(), 1);
        assert_eq!(caps[0].layer, 1);
    }

    #[test]
    fn rope_preserves_norm_and_position_zero() {
        let mut rng = Rng::new(104);
        let mut x = Matrix::randn(4, 16, 1.0, &mut rng);
        let orig = x.clone();
        apply_rope(&mut x, 2, 8);
        // position 0 unchanged
        for c in 0..16 {
            assert!((x.at(0, c) - orig.at(0, c)).abs() < 1e-6);
        }
        // rotation preserves per-row norm
        for r in 0..4 {
            let n1: f32 = orig.row(r).iter().map(|v| v * v).sum();
            let n2: f32 = x.row(r).iter().map(|v| v * v).sum();
            assert!((n1 - n2).abs() < 1e-3);
        }
    }

    #[test]
    fn forward_step_bit_identical_to_whole_sequence() {
        // prefill-then-decode must reproduce forward() bit for bit: prefill
        // the first 7 tokens in one step, then decode the rest one by one
        let mut rng = Rng::new(110);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        let tokens: Vec<u32> = (0..12).map(|_| rng.below(32) as u32).collect();
        let full = lm.forward(&tokens);
        let mut cache = SeqKv::new(cfg.layers, cfg.hidden, tokens.len());
        let prefill = lm.forward_step(&tokens[..7], &mut cache);
        assert_eq!(cache.len(), 7);
        assert_eq!((prefill.rows, prefill.cols), (7, cfg.vocab));
        for pos in 0..7 {
            for c in 0..cfg.vocab {
                assert_eq!(
                    prefill.at(pos, c).to_bits(),
                    full.at(pos, c).to_bits(),
                    "prefill logits diverged at ({pos}, {c})"
                );
            }
        }
        for pos in 7..tokens.len() {
            let step = lm.forward_step(&tokens[pos..pos + 1], &mut cache);
            assert_eq!(step.rows, 1);
            for c in 0..cfg.vocab {
                assert_eq!(
                    step.at(0, c).to_bits(),
                    full.at(pos, c).to_bits(),
                    "decode logits diverged at ({pos}, {c})"
                );
            }
        }
        assert_eq!(cache.len(), tokens.len());
    }

    #[test]
    fn forward_step_chunked_prefill_matches_any_split() {
        // the scheduler may chunk a prompt arbitrarily; every split must
        // land on the same bits
        let mut rng = Rng::new(111);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        let tokens: Vec<u32> = (0..10).map(|_| rng.below(32) as u32).collect();
        let full = lm.forward(&tokens);
        for split in [1usize, 3, 5, 9] {
            let mut cache = SeqKv::new(cfg.layers, cfg.hidden, tokens.len());
            let a = lm.forward_step(&tokens[..split], &mut cache);
            let b = lm.forward_step(&tokens[split..], &mut cache);
            for pos in 0..tokens.len() {
                let (m, r) = if pos < split { (&a, pos) } else { (&b, pos - split) };
                for c in 0..cfg.vocab {
                    assert_eq!(
                        m.at(r, c).to_bits(),
                        full.at(pos, c).to_bits(),
                        "split {split}: logits diverged at ({pos}, {c})"
                    );
                }
            }
        }
    }

    #[test]
    fn forward_step_paged_gather_bit_identical_across_page_sizes() {
        // the paged gather crosses page boundaries mid-prefix; any page
        // size must land on the same bits as the whole-sequence forward
        let mut rng = Rng::new(114);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        let tokens: Vec<u32> = (0..11).map(|_| rng.below(32) as u32).collect();
        let full = lm.forward(&tokens);
        for page in [1usize, 2, 3, 4, 16] {
            let mut cache = SeqKv::with_page_size(cfg.layers, cfg.hidden, tokens.len(), page);
            let prefill = lm.forward_step(&tokens[..5], &mut cache);
            for pos in 0..5 {
                for c in 0..cfg.vocab {
                    assert_eq!(
                        prefill.at(pos, c).to_bits(),
                        full.at(pos, c).to_bits(),
                        "page {page}: prefill logits diverged at ({pos}, {c})"
                    );
                }
            }
            for pos in 5..tokens.len() {
                let step = lm.forward_step(&tokens[pos..pos + 1], &mut cache);
                for c in 0..cfg.vocab {
                    assert_eq!(
                        step.at(0, c).to_bits(),
                        full.at(pos, c).to_bits(),
                        "page {page}: decode logits diverged at ({pos}, {c})"
                    );
                }
            }
        }
    }

    #[test]
    fn forward_step_dense_first_layer() {
        let mut cfg = tiny_cfg();
        cfg.dense_first = true;
        let mut rng = Rng::new(112);
        let lm = MoeLm::random(&cfg, &mut rng);
        let tokens: Vec<u32> = (0..6).map(|_| rng.below(32) as u32).collect();
        let full = lm.forward(&tokens);
        let mut cache = SeqKv::new(cfg.layers, cfg.hidden, tokens.len());
        let mut got = Vec::new();
        for pos in 0..tokens.len() {
            let step = lm.forward_step(&tokens[pos..pos + 1], &mut cache);
            got.push(step);
        }
        for (pos, step) in got.iter().enumerate() {
            for c in 0..cfg.vocab {
                assert_eq!(step.at(0, c).to_bits(), full.at(pos, c).to_bits());
            }
        }
    }

    #[test]
    fn forward_step_batch_concatenates_moe_rows() {
        // two sequences stepped together must match each stepped alone —
        // the MoE hook sees concatenated rows but the math is per-row
        let mut rng = Rng::new(113);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        let s1: Vec<u32> = (0..5).map(|_| rng.below(32) as u32).collect();
        let s2: Vec<u32> = (0..8).map(|_| rng.below(32) as u32).collect();
        let f1 = lm.forward(&s1);
        let f2 = lm.forward(&s2);
        let mut c1 = SeqKv::new(cfg.layers, cfg.hidden, s1.len());
        let mut c2 = SeqKv::new(cfg.layers, cfg.hidden, s2.len());
        let mut seqs = [
            StepSeq { tokens: &s1, cache: &mut c1 },
            StepSeq { tokens: &s2, cache: &mut c2 },
        ];
        let mut hook_rows = Vec::new();
        let out = lm.forward_step_batch_with_moe(&mut seqs, |_, block, x| {
            hook_rows.push(x.rows);
            block.forward(x)
        });
        assert!(hook_rows.iter().all(|&r| r == s1.len() + s2.len()), "{hook_rows:?}");
        for (m, f) in out.iter().zip([&f1, &f2]) {
            for (a, b) in m.data.iter().zip(&f.data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn forward_step_quantized_matches_forward_quantized() {
        use crate::moe::block::{uniform_schemes, QuantizedMoeBlock, WeightQuantizer};
        use crate::quant::QuantScheme;
        let mut rng = Rng::new(114);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        let tokens: Vec<u32> = (0..9).map(|_| rng.below(32) as u32).collect();
        // mixed plan: layer 0 w4a4-ish, layer 1 w8a8-ish fake quant
        let blocks: Vec<QuantizedMoeBlock> = lm
            .moe_blocks()
            .iter()
            .enumerate()
            .map(|(pos, (_, b))| {
                let scheme = if pos == 0 { QuantScheme::W4A4 } else { QuantScheme::W8A8 };
                QuantizedMoeBlock::build(
                    b,
                    &uniform_schemes(b.total_experts(), scheme),
                    &WeightQuantizer::Rtn,
                    None,
                )
                .unwrap()
            })
            .collect();
        let replacements: HashMap<usize, &QuantizedMoeBlock> = lm
            .moe_blocks()
            .iter()
            .map(|(l, _)| *l)
            .zip(blocks.iter())
            .collect();
        let full = lm.forward_quantized(&tokens, &replacements);
        let mut cache = SeqKv::new(cfg.layers, cfg.hidden, tokens.len());
        let prefill = lm.forward_step_quantized(&tokens[..4], &mut cache, &replacements);
        for pos in 0..4 {
            for c in 0..cfg.vocab {
                assert_eq!(prefill.at(pos, c).to_bits(), full.at(pos, c).to_bits());
            }
        }
        for pos in 4..tokens.len() {
            let step = lm.forward_step_quantized(&tokens[pos..pos + 1], &mut cache, &replacements);
            for c in 0..cfg.vocab {
                assert_eq!(
                    step.at(0, c).to_bits(),
                    full.at(pos, c).to_bits(),
                    "quantized decode diverged at ({pos}, {c})"
                );
            }
        }
    }

    #[test]
    fn rope_at_absolute_positions_matches_row_index() {
        let mut rng = Rng::new(115);
        let full = Matrix::randn(6, 16, 1.0, &mut rng);
        // rotating rows 4..6 with start_pos 4 must equal rows 4..6 of the
        // full rotation
        let mut a = full.clone();
        apply_rope(&mut a, 2, 8);
        let mut tail = full.gather_rows(&[4, 5]);
        apply_rope_at(&mut tail, 2, 8, 4);
        for i in 0..2 {
            for c in 0..16 {
                assert_eq!(tail.at(i, c).to_bits(), a.at(4 + i, c).to_bits());
            }
        }
    }

    #[test]
    fn mxt_roundtrip_via_save_load() {
        use crate::ser::mxt::MxtTensor;
        let mut rng = Rng::new(105);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        // serialize to MXT and reload
        let mut f = MxtFile::new();
        f.insert("embed", MxtTensor::from_f32(vec![cfg.vocab, cfg.hidden], &lm.embed.data));
        f.insert("ln_f", MxtTensor::from_f32(vec![cfg.hidden], &lm.ln_f));
        f.insert("head", MxtTensor::from_f32(vec![cfg.vocab, cfg.hidden], &lm.head.data));
        for (l, layer) in lm.layers.iter().enumerate() {
            let p = |s: &str| format!("layers.{l}.{s}");
            f.insert(&p("ln1"), MxtTensor::from_f32(vec![cfg.hidden], &layer.ln1));
            f.insert(&p("ln2"), MxtTensor::from_f32(vec![cfg.hidden], &layer.ln2));
            for (n, m) in [("wq", &layer.wq), ("wk", &layer.wk), ("wv", &layer.wv), ("wo", &layer.wo)] {
                f.insert(&p(n), MxtTensor::from_f32(vec![m.rows, m.cols], &m.data));
            }
            if let Ffn::Moe(b) = &layer.ffn {
                f.insert(&p("router"), MxtTensor::from_f32(vec![b.w_router.rows, b.w_router.cols], &b.w_router.data));
                for (e, ew) in b.experts.iter().enumerate() {
                    for (n, m) in [("gate", &ew.gate), ("up", &ew.up), ("down", &ew.down)] {
                        f.insert(&p(&format!("expert.{e}.{n}")), MxtTensor::from_f32(vec![m.rows, m.cols], &m.data));
                    }
                }
                for (s, ew) in b.shared.iter().enumerate() {
                    for (n, m) in [("gate", &ew.gate), ("up", &ew.up), ("down", &ew.down)] {
                        f.insert(&p(&format!("shared.{s}.{n}")), MxtTensor::from_f32(vec![m.rows, m.cols], &m.data));
                    }
                }
            }
        }
        let lm2 = MoeLm::load_mxt(&cfg, &f).unwrap();
        let tokens = [3u32, 1, 4, 1, 5];
        let a = lm.forward(&tokens);
        let b = lm2.forward(&tokens);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(x, y);
        }
    }
}
