//! Token-to-expert routing (§2.2): softmax gating, top-k selection with
//! renormalization, and per-expert token grouping for Group-GEMM dispatch.

use crate::tensor::matrix::matmul_nt;
use crate::tensor::ops::topk;
use crate::tensor::{softmax_rows, Matrix};

/// Routing decision for a batch of tokens.
#[derive(Clone, Debug)]
pub struct Routing {
    /// Per token: the selected `(expert, gate_weight)` pairs (len = top-k).
    pub per_token: Vec<Vec<(usize, f32)>>,
    /// Per expert: indices of the tokens routed to it (the Group-GEMM
    /// sub-problem rows) and the matching gate weights.
    pub per_expert: Vec<(Vec<usize>, Vec<f32>)>,
}

impl Routing {
    /// Tokens assigned to expert `e`.
    pub fn tokens_of(&self, e: usize) -> &[usize] {
        &self.per_expert[e].0
    }

    /// Activation counts per expert — the Fig. 1b histogram input.
    pub fn activation_counts(&self) -> Vec<usize> {
        self.per_expert.iter().map(|(t, _)| t.len()).collect()
    }
}

/// Route `x` (`[tokens, hidden]`) through gate weights `w_router`
/// (`[n_experts, hidden]`), selecting `k` experts per token with softmax
/// probabilities renormalized over the selected set.
pub fn route(x: &Matrix, w_router: &Matrix, k: usize) -> Routing {
    let n_experts = w_router.rows;
    assert!(k >= 1 && k <= n_experts);
    let mut logits = matmul_nt(x, w_router);
    softmax_rows(&mut logits);
    let mut per_token = Vec::with_capacity(x.rows);
    let mut per_expert: Vec<(Vec<usize>, Vec<f32>)> =
        (0..n_experts).map(|_| (Vec::new(), Vec::new())).collect();
    for t in 0..x.rows {
        let picks = topk(logits.row(t), k);
        let z: f32 = picks.iter().map(|p| p.1).sum();
        let picks: Vec<(usize, f32)> =
            picks.into_iter().map(|(e, w)| (e, w / z)).collect();
        for &(e, w) in &picks {
            per_expert[e].0.push(t);
            per_expert[e].1.push(w);
        }
        per_token.push(picks);
    }
    Routing { per_token, per_expert }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn every_token_gets_k_experts() {
        let mut rng = Rng::new(70);
        let x = Matrix::randn(33, 16, 1.0, &mut rng);
        let w = Matrix::randn(10, 16, 1.0, &mut rng);
        let r = route(&x, &w, 3);
        assert_eq!(r.per_token.len(), 33);
        for picks in &r.per_token {
            assert_eq!(picks.len(), 3);
            let s: f32 = picks.iter().map(|p| p.1).sum();
            assert!((s - 1.0).abs() < 1e-5, "weights renormalized");
            // distinct experts
            let mut es: Vec<usize> = picks.iter().map(|p| p.0).collect();
            es.dedup();
            assert_eq!(es.len(), 3);
        }
    }

    #[test]
    fn per_expert_grouping_consistent() {
        let mut rng = Rng::new(71);
        let x = Matrix::randn(50, 8, 1.0, &mut rng);
        let w = Matrix::randn(6, 8, 1.0, &mut rng);
        let r = route(&x, &w, 2);
        let total: usize = r.activation_counts().iter().sum();
        assert_eq!(total, 50 * 2);
        // cross-check membership
        for (e, (tokens, weights)) in r.per_expert.iter().enumerate() {
            assert_eq!(tokens.len(), weights.len());
            for (i, &t) in tokens.iter().enumerate() {
                let found = r.per_token[t].iter().find(|p| p.0 == e).unwrap();
                assert_eq!(found.1, weights[i]);
            }
        }
    }

    #[test]
    fn biased_router_skews_activation() {
        // a router with one dominant direction produces skewed frequencies,
        // the heterogeneity MxMoE exploits (Fig. 1b right)
        let mut rng = Rng::new(72);
        let x = Matrix::randn(200, 8, 1.0, &mut rng);
        let mut w = Matrix::randn(16, 8, 0.1, &mut rng);
        for c in 0..8 {
            *w.at_mut(3, c) = 2.0; // expert 3 loved by everyone
        }
        let r = route(&x, &w, 2);
        let counts = r.activation_counts();
        let max = *counts.iter().max().unwrap();
        let min_nonzero = counts.iter().copied().filter(|&c| c > 0).min().unwrap();
        assert_eq!(counts[3], max);
        assert!(max >= 10 * min_nonzero.max(1) || min_nonzero == max);
    }
}
