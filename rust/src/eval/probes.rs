//! Probe tasks — the downstream-accuracy analogues of Tab. 1's lm-eval suite.
//!
//! * **bigram** — given a frequent token, does the model's top-1 prediction
//!   match the corpus's most likely successor? (local statistics)
//! * **cloze** — top-1 accuracy on held-out validation continuations.
//!   (general language modelling)
//! * **copy** — induction: in `… A B … A`, predict `B` again. (in-context
//!   pattern matching; famously sensitive to precision loss)

use std::collections::HashMap;

use crate::data::Corpus;
use crate::moe::{MoeLm, QuantizedMoeBlock};
use crate::util::Rng;

/// Accuracy of the three probes (fractions in `[0,1]`).
#[derive(Clone, Debug)]
pub struct ProbeReport {
    pub bigram: f64,
    pub cloze: f64,
    pub copy: f64,
}

impl ProbeReport {
    pub fn mean(&self) -> f64 {
        (self.bigram + self.cloze + self.copy) / 3.0
    }
}

fn argmax(row: &[f32]) -> u32 {
    let mut best = 0usize;
    for i in 1..row.len() {
        if row[i] > row[best] {
            best = i;
        }
    }
    best as u32
}

/// Run all probes on (optionally quantized) `lm`.
pub fn probe_accuracy(
    lm: &MoeLm,
    corpus: &Corpus,
    replacements: &HashMap<usize, &QuantizedMoeBlock>,
    n_cases: usize,
    seed: u64,
) -> ProbeReport {
    let mut rng = Rng::new(seed);
    let seq_len = lm.cfg.seq_len.min(64);

    // --- bigram: prime with a real context ending in a frequent token ---
    let contexts = corpus.sequences("valid", seq_len);
    let mut bigram_ok = 0usize;
    let mut bigram_n = 0usize;
    for _ in 0..n_cases {
        let ctx = contexts[rng.below(contexts.len() as u64) as usize];
        let last = ctx[ctx.len() - 1];
        if corpus.successor_mass(last) < 30 {
            continue;
        }
        let logits = lm.forward_quantized(ctx, replacements);
        let pred = argmax(logits.row(ctx.len() - 1));
        if pred == corpus.top_successor(last) {
            bigram_ok += 1;
        }
        bigram_n += 1;
    }

    // --- cloze: top-1 accuracy on actual next tokens ---
    let mut cloze_ok = 0usize;
    let mut cloze_n = 0usize;
    for _ in 0..n_cases {
        let ctx = contexts[rng.below(contexts.len() as u64) as usize];
        let logits = lm.forward_quantized(ctx, replacements);
        // score the last 8 positions of the sequence
        for pos in ctx.len().saturating_sub(9)..ctx.len() - 1 {
            if argmax(logits.row(pos)) == ctx[pos + 1] {
                cloze_ok += 1;
            }
            cloze_n += 1;
        }
    }

    // --- copy/induction: splice a repeated rare pair into a real context ---
    let mut copy_ok = 0usize;
    let mut copy_n = 0usize;
    for _ in 0..n_cases {
        let ctx = contexts[rng.below(contexts.len() as u64) as usize];
        let mut seq = ctx.to_vec();
        let a = rng.below(lm.cfg.vocab as u64) as u32;
        let b = rng.below(lm.cfg.vocab as u64) as u32;
        let n = seq.len();
        // plant "A B" early and "A" at the end → model should emit B
        seq[n / 4] = a;
        seq[n / 4 + 1] = b;
        seq[n - 1] = a;
        let logits = lm.forward_quantized(&seq, replacements);
        if argmax(logits.row(n - 1)) == b {
            copy_ok += 1;
        }
        copy_n += 1;
    }

    ProbeReport {
        bigram: bigram_ok as f64 / bigram_n.max(1) as f64,
        cloze: cloze_ok as f64 / cloze_n.max(1) as f64,
        copy: copy_ok as f64 / copy_n.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CorpusSpec;
    use crate::moe::ModelConfig;

    #[test]
    fn probes_run_and_bounded() {
        let cfg = ModelConfig {
            name: "tiny".into(),
            vocab: 64,
            hidden: 16,
            layers: 2,
            heads: 2,
            n_experts: 4,
            n_shared: 0,
            topk: 2,
            inter: 8,
            dense_first: false,
            seq_len: 32,
        };
        let mut rng = Rng::new(120);
        let lm = MoeLm::random(&cfg, &mut rng);
        let corpus = Corpus::generate(&CorpusSpec { vocab: 64, ..Default::default() }, 20_000, 4_000);
        let rep = probe_accuracy(&lm, &corpus, &HashMap::new(), 10, 7);
        for v in [rep.bigram, rep.cloze, rep.copy] {
            assert!((0.0..=1.0).contains(&v));
        }
        assert!((0.0..=1.0).contains(&rep.mean()));
    }
}
