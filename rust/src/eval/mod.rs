//! Model quality evaluation: perplexity and probe tasks.
//!
//! These are the substitutes for WikiText-2 PPL and the seven lm-eval tasks
//! of Tab. 1 (see DESIGN.md §2): the claims under test are *relative*
//! degradations between quantization methods, which these metrics expose on
//! the mini models.

pub mod ppl;
pub mod probes;

pub use ppl::{perplexity, perplexity_quantized};
pub use probes::{probe_accuracy, ProbeReport};
