//! Next-token perplexity.

use std::collections::HashMap;

use crate::moe::{MoeLm, QuantizedMoeBlock};
use crate::tensor::Matrix;

/// Log-softmax cross-entropy of the realized next tokens, summed; returns
/// `(total_nll, token_count)`.
fn sequence_nll(logits: &Matrix, tokens: &[u32]) -> (f64, usize) {
    let t = tokens.len();
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for pos in 0..t - 1 {
        let row = logits.row(pos);
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
        let z: f64 = row.iter().map(|&v| ((v as f64) - m).exp()).sum();
        let target = tokens[pos + 1] as usize;
        let logp = (logits.at(pos, target) as f64 - m) - z.ln();
        nll -= logp;
        count += 1;
    }
    (nll, count)
}

/// Perplexity of `lm` over token sequences.
pub fn perplexity(lm: &MoeLm, seqs: &[&[u32]]) -> f64 {
    perplexity_quantized(lm, seqs, &HashMap::new())
}

/// Perplexity with some MoE layers replaced by quantized blocks.
pub fn perplexity_quantized(
    lm: &MoeLm,
    seqs: &[&[u32]],
    replacements: &HashMap<usize, &QuantizedMoeBlock>,
) -> f64 {
    assert!(!seqs.is_empty());
    let mut nll = 0.0;
    let mut count = 0usize;
    for seq in seqs {
        let logits = lm.forward_quantized(seq, replacements);
        let (n, c) = sequence_nll(&logits, seq);
        nll += n;
        count += c;
    }
    (nll / count as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::ModelConfig;
    use crate::util::Rng;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            vocab: 32,
            hidden: 16,
            layers: 2,
            heads: 2,
            n_experts: 4,
            n_shared: 0,
            topk: 2,
            inter: 8,
            dense_first: false,
            seq_len: 16,
        }
    }

    #[test]
    fn random_model_ppl_near_uniform() {
        let mut rng = Rng::new(110);
        let mut lm = MoeLm::random(&tiny_cfg(), &mut rng);
        // zero head ⇒ exactly uniform prediction ⇒ ppl = vocab
        lm.head = Matrix::zeros(32, 16);
        let seq: Vec<u32> = (0..16).map(|_| rng.below(32) as u32).collect();
        let ppl = perplexity(&lm, &[&seq]);
        assert!((ppl - 32.0).abs() < 1e-6, "ppl {ppl}");
    }

    #[test]
    fn better_than_uniform_when_biased() {
        // a head biased towards the true next token lowers ppl below vocab
        let mut rng = Rng::new(111);
        let lm = MoeLm::random(&tiny_cfg(), &mut rng);
        let seq: Vec<u32> = (0..16).map(|_| rng.below(32) as u32).collect();
        let ppl = perplexity(&lm, &[&seq]);
        assert!(ppl > 1.0);
        assert!(ppl.is_finite());
    }
}
