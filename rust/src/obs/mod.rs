//! Observability: end-to-end request tracing and SLO accounting
//! (DESIGN.md §Observability).
//!
//! The serving stack reports aggregates — percentiles, wave fill, replan
//! history — but aggregates cannot answer "why was *this* request slow?".
//! This module records per-request lifecycle spans (admit → queued →
//! batch-cut → routed → waves/decode steps → terminal) plus engine-level
//! spans (replan solve, swap staging, swap install) into per-thread
//! bounded ring collectors, drains them into a [`TraceLog`] at shutdown,
//! and exports three ways:
//!
//! * Chrome trace-event JSON ([`TraceLog::write_chrome_trace`]) — open in
//!   Perfetto (<https://ui.perfetto.dev>) or `chrome://tracing`;
//! * a JSONL structured event log ([`TraceLog::write_jsonl`]);
//! * a Prometheus-style text snapshot of the server counters
//!   ([`export::prometheus_text`]).
//!
//! Collection is compile-free switchable at runtime ([`TraceConfig`]) and
//! lock-free on the serving threads: every collector is *owned* by exactly
//! one thread (admission events ride the admission mutex the front door
//! already takes), so tracing adds no contention to the hot path.

pub mod collector;
pub mod export;
pub mod span;

pub use collector::{SpanCollector, TraceConfig};
pub use export::{validate_chrome_trace, TraceCheck, TraceLog};
pub use span::{Deadline, EventKind, Outcome, Track, TraceClock, TraceEvent};
