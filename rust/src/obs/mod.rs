//! Observability: end-to-end request tracing and SLO accounting
//! (DESIGN.md §Observability).
//!
//! The serving stack reports aggregates — percentiles, wave fill, replan
//! history — but aggregates cannot answer "why was *this* request slow?".
//! This module records per-request lifecycle spans (admit → queued →
//! batch-cut → routed → waves/decode steps → terminal) plus engine-level
//! spans (replan solve, swap staging, swap install) into per-thread
//! bounded ring collectors, drains them into a [`TraceLog`] at shutdown,
//! and exports three ways:
//!
//! * Chrome trace-event JSON ([`TraceLog::write_chrome_trace`]) — open in
//!   Perfetto (<https://ui.perfetto.dev>) or `chrome://tracing`;
//! * a JSONL structured event log ([`TraceLog::write_jsonl`]);
//! * a Prometheus-style text snapshot of the server counters
//!   ([`export::prometheus_text`]).
//!
//! Collection is compile-free switchable at runtime ([`TraceConfig`]) and
//! lock-free on the serving threads: every collector is *owned* by exactly
//! one thread (admission events ride the admission mutex the front door
//! already takes), so tracing adds no contention to the hot path.
//!
//! Two companion subsystems extend tracing from per-request depth to
//! fleet breadth (DESIGN.md §Fleet-Observatory): [`timeseries`] — an
//! [`Observatory`] registry of bounded ring series fed by an
//! off-by-default [`Sampler`] thread — and [`provenance`] — a
//! [`ProvenanceLedger`] recording every installed plan with the
//! per-(layer, expert) score terms that chose each scheme. Both surface
//! through `GET /v1/status`, the `GET /debug` dashboard, and
//! `mxmoe status`.

pub mod collector;
pub mod export;
pub mod provenance;
pub mod span;
pub mod timeseries;

pub use collector::{SpanCollector, TraceConfig};
pub use export::{validate_chrome_trace, TraceCheck, TraceLog};
pub use provenance::{
    build_record, Explanation, PlanContext, PlanRecord, PlanTrigger, ProvenanceLedger,
    SlotDecision, PROVENANCE_HISTORY,
};
pub use span::{Deadline, EventKind, Outcome, Track, TraceClock, TraceEvent};
pub use timeseries::{
    record_sample, HistogramSnapshot, Observatory, ObservatorySnapshot, Point, SampleConfig,
    Sampler, SeriesKind, SeriesSnapshot,
};
