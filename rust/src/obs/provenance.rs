//! Plan-provenance ledger (DESIGN.md §Fleet-Observatory).
//!
//! A replan event says *that* the plan changed; it does not say *why a
//! given (layer, expert) ended up at its scheme*. This module records, at
//! boot and at every replan install, the full per-slot decision with the
//! decomposed MCKP score terms — calibration sensitivity, live routing
//! frequency, measured scheme speed, stored weight bits, and the
//! QoS-blended `r` the solve ran with — plus the diff against the
//! previous plan. The ledger is a bounded deque shared between the
//! replica threads (writers, once per replan — cold path) and the status
//! endpoint / dashboard / CLI (readers), queryable as "why does expert
//! (l,e) run at W4A8 right now?" via [`ProvenanceLedger::explain`].

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::alloc::{Allocation, SensitivityTable};
use crate::moe::ModelConfig;
use crate::runtime::RuntimeScheme;

/// What produced a plan record.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanTrigger {
    /// The boot allocation a replica started serving with.
    Boot,
    /// A drift-triggered MCKP re-solve whose staged swap was installed.
    Replan,
}

impl PlanTrigger {
    pub fn name(self) -> &'static str {
        match self {
            PlanTrigger::Boot => "boot",
            PlanTrigger::Replan => "replan",
        }
    }
}

/// One (layer, expert) slot's chosen scheme with its decomposed score
/// terms — everything the MCKP objective `L^r · T^(1−r)` weighed.
#[derive(Clone, Debug)]
pub struct SlotDecision {
    /// Transformer layer index of the MoE block.
    pub layer: usize,
    /// Expert slot (routed experts first, then shared).
    pub expert: usize,
    /// Shared-expert slot (always active; frequency pinned to 1.0).
    pub shared: bool,
    /// Runtime family the slot executes under.
    pub scheme: RuntimeScheme,
    /// Exact allocator scheme of the gate linear (e.g. `w4a4_g128_sym`).
    pub quant: String,
    /// Family under the previous plan (`None` for a boot record).
    pub prev: Option<RuntimeScheme>,
    /// Did this replan change the slot's family?
    pub changed: bool,
    /// Calibration sensitivity Δ summed over the slot's three linears
    /// (0.0 when no sensitivity table was available — offline replicas).
    pub sensitivity: f64,
    /// Live routing frequency the solve saw (1.0 for shared slots).
    pub freq: f64,
    /// Mean stored weight bits across the slot's three linears.
    pub bits: f64,
    /// Measured useful rows/s of the slot's family from wave telemetry
    /// (`None` before the family has executed any wave).
    pub speed_rows_per_s: Option<f64>,
}

/// One installed plan: solve-level context plus every slot's decision.
#[derive(Clone, Debug)]
pub struct PlanRecord {
    pub replica: usize,
    /// Hot-swap generation serving this plan (0 = boot).
    pub generation: u64,
    /// Seconds since the replica's engine started.
    pub at_s: f64,
    pub trigger: PlanTrigger,
    /// TV drift that triggered the solve (0.0 at boot).
    pub drift: f64,
    /// QoS-blended accuracy/perf exponent the solve ran with.
    pub r: f64,
    pub bits_before: f64,
    pub bits_after: f64,
    pub decisions: Vec<SlotDecision>,
}

impl PlanRecord {
    /// Slots whose runtime family changed vs the previous plan.
    pub fn changed(&self) -> usize {
        self.decisions.iter().filter(|d| d.changed).count()
    }
}

/// Inputs to [`build_record`]: the installed plan plus everything the
/// solve weighed. `speeds` is (family, measured useful rows/s).
pub struct PlanContext<'a> {
    pub cfg: &'a ModelConfig,
    pub alloc: &'a Allocation,
    pub prev: Option<&'a Allocation>,
    pub freqs: &'a [Vec<f64>],
    pub sens: Option<&'a SensitivityTable>,
    pub speeds: &'a [(RuntimeScheme, f64)],
    pub r: f64,
    pub drift: f64,
}

/// Decompose an installed allocation into per-slot decisions.
pub fn build_record(replica: usize, trigger: PlanTrigger, ctx: &PlanContext) -> PlanRecord {
    let bits_after = ctx.alloc.avg_weight_bits(ctx.cfg);
    let bits_before = ctx.prev.map_or(bits_after, |p| p.avg_weight_bits(ctx.cfg));
    let mut decisions = Vec::new();
    for (pos, experts) in ctx.alloc.schemes.iter().enumerate() {
        let layer = ctx.alloc.layers.get(pos).copied().unwrap_or(pos);
        for (e, linears) in experts.iter().enumerate() {
            let scheme = RuntimeScheme::from_quant(&linears[0]);
            let prev = ctx
                .prev
                .and_then(|p| p.schemes.get(pos))
                .and_then(|block| block.get(e))
                .map(|l| RuntimeScheme::from_quant(&l[0]));
            let shared = e >= ctx.cfg.n_experts;
            let freq = if shared {
                1.0
            } else {
                ctx.freqs.get(pos).and_then(|f| f.get(e)).copied().unwrap_or(0.0)
            };
            let sensitivity = ctx
                .sens
                .filter(|t| pos < t.delta.len() && e < t.delta[pos].len())
                .map_or(0.0, |t| (0..3).map(|j| t.delta(pos, e, j, &linears[j])).sum::<f64>());
            let bits = linears.iter().map(|s| s.wbits as f64).sum::<f64>() / 3.0;
            let speed_rows_per_s =
                ctx.speeds.iter().find(|(s, _)| *s == scheme).map(|(_, v)| *v);
            decisions.push(SlotDecision {
                layer,
                expert: e,
                shared,
                scheme,
                quant: linears[0].name(),
                prev,
                changed: prev.is_some_and(|p| p != scheme),
                sensitivity,
                freq,
                bits,
                speed_rows_per_s,
            });
        }
    }
    PlanRecord {
        replica,
        generation: 0,
        at_s: 0.0,
        trigger,
        drift: ctx.drift,
        r: ctx.r,
        bits_before,
        bits_after,
        decisions,
    }
}

/// The answer to "why does expert (l,e) run at its scheme?": the newest
/// recorded decision for that slot plus its solve context.
#[derive(Clone, Debug)]
pub struct Explanation {
    pub replica: usize,
    pub generation: u64,
    pub at_s: f64,
    pub trigger: PlanTrigger,
    pub r: f64,
    pub drift: f64,
    pub decision: SlotDecision,
}

impl Explanation {
    /// One-line human rendering for the CLI and dashboard.
    pub fn describe(&self) -> String {
        let d = &self.decision;
        let speed = d
            .speed_rows_per_s
            .map_or("unmeasured".to_string(), |v| format!("{v:.0} rows/s"));
        format!(
            "layer {} expert {}{} runs {} ({}) since {} at {:.2}s (gen {}): \
             sensitivity {:.4e}, live freq {:.3}, speed {}, {:.2} bits, r {:.2}, drift {:.3}",
            d.layer,
            d.expert,
            if d.shared { " (shared)" } else { "" },
            d.scheme.name(),
            d.quant,
            self.trigger.name(),
            self.at_s,
            self.generation,
            d.sensitivity,
            d.freq,
            speed,
            d.bits,
            self.r,
            self.drift,
        )
    }
}

/// Plan records retained per cluster (bounded deque, newest kept).
pub const PROVENANCE_HISTORY: usize = 16;

/// Bounded, shared ledger of installed plans. Written once per replan —
/// far off the serving hot path — so a plain mutex is plenty.
pub struct ProvenanceLedger {
    cap: usize,
    inner: Mutex<VecDeque<PlanRecord>>,
}

impl Default for ProvenanceLedger {
    fn default() -> Self {
        ProvenanceLedger::new(PROVENANCE_HISTORY)
    }
}

impl ProvenanceLedger {
    pub fn new(cap: usize) -> ProvenanceLedger {
        ProvenanceLedger { cap: cap.max(1), inner: Mutex::new(VecDeque::new()) }
    }

    /// Append a record, evicting the oldest past the capacity.
    pub fn record(&self, rec: PlanRecord) {
        let mut g = self.inner.lock().unwrap();
        if g.len() >= self.cap {
            g.pop_front();
        }
        g.push_back(rec);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().is_empty()
    }

    /// All retained records, oldest first.
    pub fn records(&self) -> Vec<PlanRecord> {
        self.inner.lock().unwrap().iter().cloned().collect()
    }

    /// The newest record (any replica).
    pub fn latest(&self) -> Option<PlanRecord> {
        self.inner.lock().unwrap().back().cloned()
    }

    /// Why does expert (`layer`, `expert`) run at its current scheme? The
    /// newest record holding a decision for that slot, newest-plan wins.
    pub fn explain(&self, layer: usize, expert: usize) -> Option<Explanation> {
        let g = self.inner.lock().unwrap();
        g.iter().rev().find_map(|rec| {
            rec.decisions
                .iter()
                .find(|d| d.layer == layer && d.expert == expert)
                .map(|d| Explanation {
                    replica: rec.replica,
                    generation: rec.generation,
                    at_s: rec.at_s,
                    trigger: rec.trigger,
                    r: rec.r,
                    drift: rec.drift,
                    decision: d.clone(),
                })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::ModelConfig;
    use crate::quant::scheme::QuantScheme;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            vocab: 32,
            hidden: 32,
            layers: 2,
            heads: 2,
            n_experts: 2,
            n_shared: 1,
            topk: 1,
            inter: 16,
            dense_first: false,
            seq_len: 16,
        }
    }

    #[test]
    fn build_record_decomposes_slots_and_diffs() {
        let cfg = tiny_cfg();
        let prev = Allocation::uniform(&cfg, QuantScheme::FP16);
        let mut alloc = prev.clone();
        alloc.schemes[0][1] = [QuantScheme::W4A4; 3];
        let freqs = vec![vec![0.25, 0.75], vec![0.5, 0.5]];
        let rec = build_record(
            3,
            PlanTrigger::Replan,
            &PlanContext {
                cfg: &cfg,
                alloc: &alloc,
                prev: Some(&prev),
                freqs: &freqs,
                sens: None,
                speeds: &[(RuntimeScheme::W4A4, 1e6)],
                r: 0.75,
                drift: 0.2,
            },
        );
        assert_eq!(rec.decisions.len(), 2 * 3, "2 blocks x (2 routed + 1 shared)");
        assert_eq!(rec.changed(), 1);
        let d = rec
            .decisions
            .iter()
            .find(|d| d.layer == alloc.layers[0] && d.expert == 1)
            .unwrap();
        assert_eq!(d.scheme, RuntimeScheme::W4A4);
        assert_eq!(d.prev, Some(RuntimeScheme::Fp16));
        assert!(d.changed);
        assert!((d.freq - 0.75).abs() < 1e-12);
        assert!((d.bits - 4.0).abs() < 1e-12);
        assert_eq!(d.speed_rows_per_s, Some(1e6));
        let shared = rec.decisions.iter().find(|d| d.expert == 2).unwrap();
        assert!(shared.shared && (shared.freq - 1.0).abs() < 1e-12);
        assert!(rec.bits_before > rec.bits_after, "one slot dropped to 4 bits");
    }

    #[test]
    fn ledger_is_bounded_and_explains_the_newest_plan() {
        let cfg = tiny_cfg();
        let ledger = ProvenanceLedger::new(2);
        let freqs = vec![vec![0.5, 0.5], vec![0.5, 0.5]];
        for gen in 0..3u64 {
            let scheme = if gen == 2 { QuantScheme::W8A8 } else { QuantScheme::FP16 };
            let alloc = Allocation::uniform(&cfg, scheme);
            let mut rec = build_record(
                0,
                if gen == 0 { PlanTrigger::Boot } else { PlanTrigger::Replan },
                &PlanContext {
                    cfg: &cfg,
                    alloc: &alloc,
                    prev: None,
                    freqs: &freqs,
                    sens: None,
                    speeds: &[],
                    r: 0.75,
                    drift: 0.0,
                },
            );
            rec.generation = gen;
            rec.at_s = gen as f64;
            ledger.record(rec);
        }
        assert_eq!(ledger.len(), 2, "oldest record evicted");
        assert_eq!(ledger.records()[0].generation, 1);
        assert_eq!(ledger.latest().unwrap().generation, 2);
        let why = ledger.explain(cfg.moe_layers()[0], 0).unwrap();
        assert_eq!(why.generation, 2, "newest plan wins");
        assert_eq!(why.decision.scheme, RuntimeScheme::W8A8);
        assert!(why.describe().contains("w8a8"));
        assert!(ledger.explain(9999, 0).is_none());
    }
}
