//! Fleet observatory: bounded ring-buffer time series (DESIGN.md
//! §Fleet-Observatory).
//!
//! Point-in-time reports show *where the cluster is*; they cannot show
//! *how it got there*. The [`Observatory`] is a registry of named series —
//! gauges, monotone counters (stored as per-sample deltas, wraparound-
//! and reset-safe), and fixed-bucket histograms — each bounded by the same
//! fill-then-overwrite cursor ring the metric latency windows use. A
//! [`Sampler`] thread polls `Cluster::live_report()` on a configurable
//! interval and folds the snapshot in through [`record_sample`]; nothing
//! on the serving hot path ever touches the registry, so — like tracing —
//! the sampler is off by default and overhead-free when off (gated ≤3%
//! with bit-identical outputs in `benches/bench_trace_overhead.rs`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::{slo_class_name, ServerReport};
use crate::runtime::RuntimeScheme;

/// Sampler on/off switch + cadence + per-series ring capacity. Mirrors
/// [`crate::obs::TraceConfig`]: compile-free, off by default, and the off
/// path costs nothing (no thread is even spawned).
#[derive(Clone, Copy, Debug)]
pub struct SampleConfig {
    pub enabled: bool,
    /// Poll interval, milliseconds.
    pub interval_ms: u64,
    /// Points retained per series; older points are overwritten.
    pub capacity: usize,
}

impl Default for SampleConfig {
    fn default() -> Self {
        SampleConfig { enabled: false, interval_ms: 250, capacity: 512 }
    }
}

impl SampleConfig {
    /// Sampling on with the default cadence and capacity.
    pub fn on() -> SampleConfig {
        SampleConfig { enabled: true, ..SampleConfig::default() }
    }

    pub fn interval(&self) -> Duration {
        Duration::from_millis(self.interval_ms.max(1))
    }
}

/// One observation: seconds since sampler start, value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Point {
    pub t_s: f64,
    pub v: f64,
}

/// What a series measures — fixes how its points are read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeriesKind {
    /// Point-in-time level; each point is the level at that sample.
    Gauge,
    /// Monotone total; each point is the *delta* since the previous
    /// sample (wraparound- and reset-safe), so a point is already a
    /// per-interval rate.
    Counter,
}

impl SeriesKind {
    pub fn name(self) -> &'static str {
        match self {
            SeriesKind::Gauge => "gauge",
            SeriesKind::Counter => "counter",
        }
    }
}

/// One bounded series: cursor ring of points plus counter state.
struct Series {
    kind: SeriesKind,
    points: Vec<Point>,
    cursor: usize,
    /// Points ever pushed (eviction accounting: retained = min(pushed, cap)).
    pushed: u64,
    /// Counters: last raw total seen, for wrapping deltas.
    last_raw: u64,
    has_raw: bool,
    last_t_s: f64,
}

impl Series {
    fn new(kind: SeriesKind) -> Series {
        Series {
            kind,
            points: Vec::new(),
            cursor: 0,
            pushed: 0,
            last_raw: 0,
            has_raw: false,
            last_t_s: 0.0,
        }
    }

    fn push(&mut self, cap: usize, p: Point) {
        if self.points.len() < cap.max(1) {
            self.points.push(p);
        } else {
            self.points[self.cursor] = p;
            self.cursor = (self.cursor + 1) % self.points.len();
        }
        self.pushed += 1;
    }

    /// Points oldest-first (un-rotates the cursor ring).
    fn ordered(&self) -> Vec<Point> {
        let mut out = Vec::with_capacity(self.points.len());
        out.extend_from_slice(&self.points[self.cursor..]);
        out.extend_from_slice(&self.points[..self.cursor]);
        out
    }
}

/// Fixed-bucket cumulative histogram (Prometheus-shaped: `bounds` are the
/// inclusive `le` upper bounds; one implicit +Inf bucket at the end).
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    pub name: String,
    pub bounds: Vec<f64>,
    /// Per-bucket (non-cumulative) counts; `len() == bounds.len() + 1`.
    pub counts: Vec<u64>,
    pub sum: f64,
    pub count: u64,
}

struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Histogram {
        Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
            count: 0,
        }
    }

    fn observe(&mut self, v: f64) {
        let slot = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[slot] += 1;
        self.sum += v;
        self.count += 1;
    }
}

/// A full copy of one series, oldest point first.
#[derive(Clone, Debug)]
pub struct SeriesSnapshot {
    pub name: String,
    pub kind: SeriesKind,
    pub points: Vec<Point>,
    /// Counters: the last raw total observed (0 for gauges).
    pub total: u64,
    /// Points ever pushed (≥ `points.len()`; the difference was evicted).
    pub pushed: u64,
}

/// Everything the observatory holds, copied out at snapshot time.
#[derive(Clone, Debug, Default)]
pub struct ObservatorySnapshot {
    pub series: Vec<SeriesSnapshot>,
    pub histograms: Vec<HistogramSnapshot>,
}

/// Registry of bounded time series. One mutex around the whole map —
/// "lock-light" because only the sampler thread (a few Hz) and the
/// occasional status/dashboard reader ever take it; serving threads never
/// touch it.
pub struct Observatory {
    capacity: usize,
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    series: BTreeMap<String, Series>,
    hists: BTreeMap<String, Histogram>,
}

impl Observatory {
    pub fn new(capacity: usize) -> Observatory {
        Observatory { capacity: capacity.max(1), inner: Mutex::new(Inner::default()) }
    }

    /// Points retained per series.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Record a gauge level. Non-finite values are dropped — a series
    /// never holds NaN/Inf, so exports never emit them.
    pub fn gauge(&self, name: &str, t_s: f64, v: f64) {
        if !v.is_finite() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        let s = g
            .series
            .entry(name.to_string())
            .or_insert_with(|| Series::new(SeriesKind::Gauge));
        s.push(self.capacity, Point { t_s, v });
        s.last_t_s = t_s;
    }

    /// Record a monotone counter's raw total; stores the delta since the
    /// previous sample. A genuine u64 wraparound (previous total near
    /// `u64::MAX`) still yields the true increment via `wrapping_sub`;
    /// any other decrease is treated as a counter reset — Prometheus
    /// `rate()` style — and records a zero delta. Cluster-summed totals
    /// reset partially when a replica respawns (`ReplicaStatus::boot`
    /// zeroes its slot), so crediting the post-reset raw total as the
    /// increment would double-count the surviving replicas; dropping one
    /// interval's increment is the bounded error. Returns the per-second
    /// rate over the elapsed interval (0.0 on the first sample and on a
    /// reset).
    pub fn counter(&self, name: &str, t_s: f64, raw: u64) -> f64 {
        let mut g = self.inner.lock().unwrap();
        let s = g
            .series
            .entry(name.to_string())
            .or_insert_with(|| Series::new(SeriesKind::Counter));
        let (delta, rate) = if s.has_raw {
            let d = if raw >= s.last_raw {
                raw - s.last_raw
            } else if s.last_raw - raw > u64::MAX / 2 {
                // the old total sat near u64::MAX: a true wraparound
                raw.wrapping_sub(s.last_raw)
            } else {
                0 // reset (e.g. replica respawn shrank the summed total)
            };
            let dt = t_s - s.last_t_s;
            (d, if dt > 0.0 { d as f64 / dt } else { 0.0 })
        } else {
            (raw, 0.0)
        };
        s.push(self.capacity, Point { t_s, v: delta as f64 });
        s.last_raw = raw;
        s.has_raw = true;
        s.last_t_s = t_s;
        rate
    }

    /// Fold one observation into a fixed-bucket histogram (created on
    /// first use with `bounds` as its `le` upper bounds).
    pub fn observe(&self, name: &str, bounds: &[f64], v: f64) {
        if !v.is_finite() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.hists
            .entry(name.to_string())
            .or_insert_with(|| Histogram::new(bounds))
            .observe(v);
    }

    /// All series names, sorted.
    pub fn series_names(&self) -> Vec<String> {
        self.inner.lock().unwrap().series.keys().cloned().collect()
    }

    /// One series' points, oldest first (empty if unknown).
    pub fn points(&self, name: &str) -> Vec<Point> {
        self.inner
            .lock()
            .unwrap()
            .series
            .get(name)
            .map(|s| s.ordered())
            .unwrap_or_default()
    }

    /// Points ever pushed into a series (retained = min(pushed, capacity)).
    pub fn pushed(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().series.get(name).map(|s| s.pushed).unwrap_or(0)
    }

    /// The series value at time `t_s`: the newest point at-or-before that
    /// instant. `None` if the series is unknown or started after `t_s`.
    /// This is the "what was queue depth at tick T?" query.
    pub fn value_at(&self, name: &str, t_s: f64) -> Option<f64> {
        let pts = self.points(name);
        pts.iter().rev().find(|p| p.t_s <= t_s + 1e-9).map(|p| p.v)
    }

    /// Copy everything out (status endpoint / dashboard / CLI).
    pub fn snapshot(&self) -> ObservatorySnapshot {
        let g = self.inner.lock().unwrap();
        ObservatorySnapshot {
            series: g
                .series
                .iter()
                .map(|(name, s)| SeriesSnapshot {
                    name: name.clone(),
                    kind: s.kind,
                    points: s.ordered(),
                    total: if s.kind == SeriesKind::Counter { s.last_raw } else { 0 },
                    pushed: s.pushed,
                })
                .collect(),
            histograms: g
                .hists
                .iter()
                .map(|(name, h)| HistogramSnapshot {
                    name: name.clone(),
                    bounds: h.bounds.clone(),
                    counts: h.counts.clone(),
                    sum: h.sum,
                    count: h.count,
                })
                .collect(),
        }
    }
}

/// Queue-depth histogram buckets (requests waiting at a sample).
pub const QUEUE_DEPTH_BUCKETS: [f64; 10] =
    [0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0];

/// Fold one live snapshot into the observatory's named series. Called by
/// the cluster's sampler thread each tick; `t_s` is seconds since the
/// sampler started, `scheme_rows` is (family, useful rows, busy seconds)
/// aggregated across replicas.
pub fn record_sample(
    obs: &Observatory,
    t_s: f64,
    report: &ServerReport,
    queued_requests: usize,
    queued_batches: usize,
    scheme_rows: &[(RuntimeScheme, usize, f64)],
) {
    obs.gauge("queue_depth", t_s, queued_requests as f64);
    obs.gauge("queued_batches", t_s, queued_batches as f64);
    obs.observe("queue_depth_hist", &QUEUE_DEPTH_BUCKETS, queued_requests as f64);
    obs.gauge("generation", t_s, report.generation as f64);

    // Admission & shed rates by reason: counters store per-interval deltas.
    obs.counter("admitted_total", t_s, report.admitted as u64);
    obs.counter("rejected_queue_full_total", t_s, report.rejected_queue_full as u64);
    obs.counter("rejected_deadline_total", t_s, report.rejected_deadline as u64);
    obs.counter("rejected_quota_total", t_s, report.rejected_quota as u64);
    obs.counter("rejected_kv_total", t_s, report.rejected_kv as u64);
    obs.counter("cancelled_total", t_s, report.cancelled as u64);
    obs.counter("failed_total", t_s, report.failed as u64);

    // Progress counters + the decode-rate gauge derived from one of them.
    obs.counter("requests_total", t_s, report.requests as u64);
    obs.counter("tokens_total", t_s, report.tokens as u64);
    let decode_rate = obs.counter("generated_tokens_total", t_s, report.generated_tokens as u64);
    obs.gauge("decode_tps", t_s, decode_rate);
    obs.counter("generations_total", t_s, report.generations as u64);
    obs.counter("replans_total", t_s, report.replans as u64);
    obs.counter("swaps_total", t_s, report.swaps as u64);

    // KV occupancy: used/shared/budget levels plus preemption rate.
    obs.gauge("kv_used_tokens", t_s, report.kv_used_tokens as f64);
    obs.gauge("kv_shared_tokens", t_s, report.kv_shared_tokens as f64);
    obs.gauge("kv_budget_tokens", t_s, report.kv_budget_tokens as f64);
    if report.kv_used_tokens > 0 {
        obs.gauge("kv_avg_bits", t_s, report.kv_avg_bits);
    }
    obs.counter("kv_preemptions_total", t_s, report.kv_preemptions as u64);

    // Per-QoS SLO hit rate (1.0 where no deadline was judged).
    for (i, slo) in report.slo_by_class.iter().enumerate() {
        obs.gauge(&format!("slo_hit_rate_{}", slo_class_name(i)), t_s, slo.hit_rate());
    }

    // Per-scheme wave work: useful-row counters (delta = occupancy per
    // interval) + cumulative busy-seconds gauges.
    for (scheme, useful_rows, busy_s) in scheme_rows {
        obs.counter(&format!("wave_rows_{}_total", scheme.name()), t_s, *useful_rows as u64);
        obs.gauge(&format!("wave_busy_s_{}", scheme.name()), t_s, *busy_s);
    }
}

/// A stoppable interval thread driving a sampling closure. The closure
/// receives seconds since the sampler started. Generic over the closure so
/// the start/stop lifecycle is unit-testable without a cluster.
pub struct Sampler {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<u64>>,
}

impl Sampler {
    /// Spawn the sampler thread: tick immediately, then every `interval`
    /// until stopped.
    pub fn spawn<F>(interval: Duration, mut tick: F) -> Sampler
    where
        F: FnMut(f64) + Send + 'static,
    {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let handle = thread::Builder::new()
            .name("mxmoe-sampler".into())
            .spawn(move || {
                let start = Instant::now();
                let mut ticks = 0u64;
                while !flag.load(Ordering::Relaxed) {
                    tick(start.elapsed().as_secs_f64());
                    ticks += 1;
                    // Sleep in short slices so stop() returns promptly
                    // even with a long interval.
                    let mut left = interval;
                    let slice = Duration::from_millis(20);
                    while left > Duration::ZERO && !flag.load(Ordering::Relaxed) {
                        let d = left.min(slice);
                        thread::sleep(d);
                        left = left.saturating_sub(d);
                    }
                }
                ticks
            })
            .expect("spawn mxmoe-sampler");
        Sampler { stop, handle: Some(handle) }
    }

    /// Signal the thread to exit and join it; returns ticks executed.
    pub fn stop(mut self) -> u64 {
        self.stop.store(true, Ordering::Relaxed);
        self.handle.take().map(|h| h.join().unwrap_or(0)).unwrap_or(0)
    }
}

impl Drop for Sampler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauge_ring_bounds_and_orders_points() {
        let obs = Observatory::new(4);
        for i in 0..10 {
            obs.gauge("depth", i as f64, (i * 10) as f64);
        }
        let pts = obs.points("depth");
        assert_eq!(pts.len(), 4, "ring is bounded");
        let ts: Vec<f64> = pts.iter().map(|p| p.t_s).collect();
        assert_eq!(ts, vec![6.0, 7.0, 8.0, 9.0], "oldest evicted, order kept");
        assert_eq!(obs.pushed("depth"), 10, "eviction is counted, not silent");
    }

    #[test]
    fn counter_deltas_survive_wraparound() {
        let obs = Observatory::new(8);
        obs.counter("c", 0.0, u64::MAX - 5);
        obs.counter("c", 1.0, u64::MAX - 1);
        let rate = obs.counter("c", 2.0, 5); // wrapped: true delta = 7
        let pts = obs.points("c");
        assert_eq!(pts[1].v, 4.0);
        assert_eq!(pts[2].v, 7.0, "wrapping_sub recovers the increment");
        assert!((rate - 7.0).abs() < 1e-9, "rate over the 1 s interval");
    }

    #[test]
    fn counter_reset_records_zero_delta() {
        let obs = Observatory::new(8);
        obs.counter("c", 0.0, 1000);
        obs.counter("c", 1.0, 2000);
        // a replica respawn shrinks the cluster-summed total: not a
        // wraparound, must not be credited as a ~u64::MAX increment
        let rate = obs.counter("c", 2.0, 600);
        let pts = obs.points("c");
        assert_eq!(pts[2].v, 0.0, "reset records a zero delta, not a wrapped one");
        assert_eq!(rate, 0.0, "no rate across a reset");
        // deltas resume from the post-reset baseline
        obs.counter("c", 3.0, 700);
        assert_eq!(obs.points("c")[3].v, 100.0);
    }

    #[test]
    fn gauges_never_store_non_finite() {
        let obs = Observatory::new(8);
        obs.gauge("g", 0.0, f64::NAN);
        obs.gauge("g", 1.0, f64::INFINITY);
        assert!(obs.points("g").is_empty());
        obs.gauge("g", 2.0, 1.5);
        assert_eq!(obs.points("g").len(), 1);
    }

    #[test]
    fn value_at_reads_nearest_at_or_before() {
        let obs = Observatory::new(16);
        obs.gauge("g", 1.0, 10.0);
        obs.gauge("g", 3.0, 30.0);
        assert_eq!(obs.value_at("g", 0.5), None, "before the first sample");
        assert_eq!(obs.value_at("g", 1.0), Some(10.0));
        assert_eq!(obs.value_at("g", 2.9), Some(10.0));
        assert_eq!(obs.value_at("g", 3.0), Some(30.0));
        assert_eq!(obs.value_at("g", 99.0), Some(30.0));
        assert_eq!(obs.value_at("missing", 1.0), None);
    }

    #[test]
    fn histogram_buckets_and_totals() {
        let obs = Observatory::new(8);
        let bounds = [1.0, 4.0, 16.0];
        for v in [0.0, 1.0, 3.0, 20.0] {
            obs.observe("h", &bounds, v);
        }
        let snap = obs.snapshot();
        let h = &snap.histograms[0];
        assert_eq!(h.name, "h");
        assert_eq!(h.counts, vec![2, 1, 0, 1], "le buckets + overflow");
        assert_eq!(h.count, 4);
        assert!((h.sum - 24.0).abs() < 1e-12);
    }

    #[test]
    fn sampler_lifecycle_ticks_then_stops() {
        use std::sync::atomic::AtomicU64;
        let n = Arc::new(AtomicU64::new(0));
        let n2 = Arc::clone(&n);
        let s = Sampler::spawn(Duration::from_millis(1), move |t_s| {
            assert!(t_s >= 0.0);
            n2.fetch_add(1, Ordering::Relaxed);
        });
        while n.load(Ordering::Relaxed) < 3 {
            thread::sleep(Duration::from_millis(1));
        }
        let ticks = s.stop();
        assert!(ticks >= 3);
        let frozen = n.load(Ordering::Relaxed);
        assert_eq!(ticks, frozen, "every tick ran the closure");
        thread::sleep(Duration::from_millis(10));
        assert_eq!(n.load(Ordering::Relaxed), frozen, "no ticks after stop");
    }

    #[test]
    fn record_sample_populates_the_standard_series() {
        let obs = Observatory::new(32);
        let mut r = ServerReport { admitted: 5, generated_tokens: 100, ..Default::default() };
        record_sample(&obs, 0.0, &r, 7, 2, &[(RuntimeScheme::Fp16, 64, 0.5)]);
        r.admitted = 9;
        r.generated_tokens = 300;
        record_sample(&obs, 1.0, &r, 3, 1, &[(RuntimeScheme::Fp16, 128, 0.9)]);
        assert_eq!(obs.value_at("queue_depth", 0.5), Some(7.0));
        assert_eq!(obs.value_at("queue_depth", 1.0), Some(3.0));
        let adm = obs.points("admitted_total");
        assert_eq!(adm[0].v, 5.0, "first sample seeds the delta with the raw total");
        assert_eq!(adm[1].v, 4.0);
        assert_eq!(obs.value_at("decode_tps", 1.0), Some(200.0), "tokens/s from the delta");
        assert_eq!(obs.points("wave_rows_fp16_total")[1].v, 64.0);
        assert!(
            obs.value_at("kv_avg_bits", 1.0).is_none(),
            "no KV pool -> no avg-bits gauge, never a stale 32.0"
        );
        let snap = obs.snapshot();
        assert_eq!(snap.histograms[0].count, 2);
        assert!(snap.series.iter().any(|s| s.name == "slo_hit_rate_interactive"));
    }
}
