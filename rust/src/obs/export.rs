//! Trace export: Chrome trace-event JSON (Perfetto-loadable), JSONL
//! structured events, a Prometheus-style counter snapshot, and the
//! validator CI runs over emitted traces (DESIGN.md §Observability).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::metrics::ServerReport;
use crate::ser::Json;

use super::span::{EventKind, Track, TraceEvent};

/// The merged, time-sorted event log of one serving run: every collector's
/// ring drained into one timeline at shutdown.
#[derive(Clone, Debug, Default)]
pub struct TraceLog {
    /// Events sorted by timestamp (admits before terminals at equal ts).
    pub events: Vec<TraceEvent>,
    /// Events overwritten in bounded rings before the drain (0 = the log
    /// is complete).
    pub dropped: usize,
}

impl TraceLog {
    pub fn empty() -> TraceLog {
        TraceLog::default()
    }

    /// Merge drained collector rings into one sorted timeline. Sorting is
    /// by timestamp with lifecycle tie-breaks (an admit sorts before a
    /// terminal recorded in the same microsecond), so the exported Chrome
    /// trace is monotonic and its async begin/end pairs nest.
    pub fn merge(parts: Vec<(Vec<TraceEvent>, usize)>) -> TraceLog {
        let mut events = Vec::new();
        let mut dropped = 0;
        for (evs, d) in parts {
            events.extend(evs);
            dropped += d;
        }
        events.sort_by_key(|e| (e.ts_us, lifecycle_rank(&e.kind), e.req));
        TraceLog { events, dropped }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Request ids admitted in this log.
    pub fn admitted_ids(&self) -> Vec<u64> {
        self.events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::Admitted { .. }))
            .map(|e| e.req)
            .collect()
    }

    /// Terminal events per request id: `(id, outcome)` in time order.
    pub fn terminals(&self) -> Vec<(u64, super::span::Outcome)> {
        self.events
            .iter()
            .filter_map(|e| match e.kind {
                EventKind::Terminal { outcome, .. } => Some((e.req, outcome)),
                _ => None,
            })
            .collect()
    }

    /// The full log as a Chrome trace-event JSON document
    /// (<https://ui.perfetto.dev> loads it directly). Request lifecycles
    /// are nestable async `b`/`e` pairs keyed by request id; waves, decode
    /// steps and replan phases are complete (`X`) spans on their thread's
    /// track; rejections and routing decisions are instants.
    pub fn chrome_trace(&self) -> Json {
        let mut out = Vec::new();
        // thread-name metadata first (ts 0 keeps the stream monotonic)
        let mut tracks: Vec<Track> = Vec::new();
        for e in &self.events {
            if !tracks.contains(&e.track) {
                tracks.push(e.track);
            }
        }
        tracks.sort_by_key(Track::tid);
        out.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("process_name")),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(0.0)),
            ("ts", Json::num(0.0)),
            ("args", Json::obj(vec![("name", Json::str("mxmoe"))])),
        ]));
        for t in &tracks {
            out.push(Json::obj(vec![
                ("ph", Json::str("M")),
                ("name", Json::str("thread_name")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(t.tid() as f64)),
                ("ts", Json::num(0.0)),
                ("args", Json::obj(vec![("name", Json::str(&t.name()))])),
            ]));
        }
        for e in &self.events {
            let mut fields = vec![
                ("name", Json::str(e.kind.name())),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(e.track.tid() as f64)),
                ("ts", Json::num(e.ts_us as f64)),
                ("args", event_args(e)),
            ];
            match &e.kind {
                EventKind::Admitted { .. } => {
                    fields.push(("ph", Json::str("b")));
                    fields.push(("cat", Json::str("request")));
                    fields.push(("id", Json::num(e.req as f64)));
                }
                EventKind::Terminal { .. } => {
                    fields.push(("ph", Json::str("e")));
                    fields.push(("cat", Json::str("request")));
                    fields.push(("id", Json::num(e.req as f64)));
                }
                EventKind::Rejected { .. }
                | EventKind::BatchCut { .. }
                | EventKind::Routed { .. }
                | EventKind::KvPreempt { .. } => {
                    fields.push(("ph", Json::str("i")));
                    fields.push(("s", Json::str("t")));
                }
                EventKind::Wave { .. }
                | EventKind::DecodeStep { .. }
                | EventKind::ReplanSolve { .. }
                | EventKind::SwapStage { .. }
                | EventKind::SwapInstall { .. }
                | EventKind::HttpConn { .. } => {
                    fields.push(("ph", Json::str("X")));
                    fields.push(("dur", Json::num(e.dur_us as f64)));
                }
            }
            out.push(Json::obj(fields));
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(out)),
            ("displayTimeUnit", Json::str("ms")),
            ("otherData", Json::obj(vec![("droppedEvents", Json::num(self.dropped as f64))])),
        ])
    }

    pub fn write_chrome_trace(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.chrome_trace().dump())
            .with_context(|| format!("write chrome trace {path:?}"))
    }

    /// One structured JSON object per line — the machine-diffable log.
    pub fn jsonl(&self) -> String {
        let mut s = String::new();
        for e in &self.events {
            let line = Json::obj(vec![
                ("ts_us", Json::num(e.ts_us as f64)),
                ("dur_us", Json::num(e.dur_us as f64)),
                ("req", Json::num(e.req as f64)),
                ("track", Json::str(&e.track.name())),
                ("event", Json::str(e.kind.name())),
                ("args", event_args(e)),
            ]);
            s.push_str(&line.dump());
            s.push('\n');
        }
        s
    }

    pub fn write_jsonl(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.jsonl()).with_context(|| format!("write jsonl {path:?}"))
    }
}

/// Sort rank at equal timestamps: admits open before anything else; a
/// terminal closes after everything else.
fn lifecycle_rank(kind: &EventKind) -> u8 {
    match kind {
        EventKind::Admitted { .. } => 0,
        EventKind::Terminal { .. } => 2,
        _ => 1,
    }
}

/// Kind-specific argument object (shared by the Chrome and JSONL exports).
fn event_args(e: &TraceEvent) -> Json {
    let req = ("req", Json::num(e.req as f64));
    match &e.kind {
        EventKind::Admitted { qos, priority, tokens } => Json::obj(vec![
            req,
            ("qos", Json::str(qos)),
            ("priority", Json::str(priority)),
            ("tokens", Json::num(*tokens as f64)),
        ]),
        EventKind::Rejected { reason } => Json::obj(vec![req, ("reason", Json::str(reason))]),
        EventKind::BatchCut { seqs, tokens, fill } => Json::obj(vec![
            ("seqs", Json::num(*seqs as f64)),
            ("tokens", Json::num(*tokens as f64)),
            ("fill", Json::num(*fill)),
        ]),
        EventKind::Routed { replica } => {
            Json::obj(vec![req, ("replica", Json::num(*replica as f64))])
        }
        EventKind::Terminal {
            outcome,
            qos,
            queue_us,
            compute_us,
            stream_us,
            generation,
            deadline,
            tokens,
        } => Json::obj(vec![
            req,
            ("outcome", Json::str(outcome.name())),
            ("qos", Json::str(qos)),
            ("queue_us", Json::num(*queue_us as f64)),
            ("compute_us", Json::num(*compute_us as f64)),
            ("stream_us", Json::num(*stream_us as f64)),
            ("generation", Json::num(*generation as f64)),
            ("deadline", Json::str(deadline.name())),
            ("tokens", Json::num(*tokens as f64)),
        ]),
        EventKind::Wave { scheme, tile_m, items, rows, padded } => Json::obj(vec![
            ("scheme", Json::str(scheme)),
            ("tile_m", Json::num(*tile_m as f64)),
            ("items", Json::num(*items as f64)),
            ("rows", Json::num(*rows as f64)),
            ("padded", Json::num(*padded as f64)),
        ]),
        EventKind::DecodeStep {
            rows,
            prefill_rows,
            decode_rows,
            tokens,
            kv_reserved,
            kv_used,
            kv_budget,
        } => Json::obj(vec![
            ("rows", Json::num(*rows as f64)),
            ("prefill_rows", Json::num(*prefill_rows as f64)),
            ("decode_rows", Json::num(*decode_rows as f64)),
            ("tokens", Json::num(*tokens as f64)),
            ("kv_reserved", Json::num(*kv_reserved as f64)),
            ("kv_used", Json::num(*kv_used as f64)),
            ("kv_budget", Json::num(*kv_budget as f64)),
        ]),
        EventKind::KvPreempt { kv_reserved, kv_budget } => Json::obj(vec![
            ("kv_reserved", Json::num(*kv_reserved as f64)),
            ("kv_budget", Json::num(*kv_budget as f64)),
        ]),
        EventKind::ReplanSolve { drift, changes } => Json::obj(vec![
            ("drift", Json::num(*drift)),
            ("changes", Json::num(*changes as f64)),
        ]),
        EventKind::SwapStage { changes } => {
            Json::obj(vec![("changes", Json::num(*changes as f64))])
        }
        EventKind::SwapInstall { swapped, generation } => Json::obj(vec![
            ("swapped", Json::num(*swapped as f64)),
            ("generation", Json::num(*generation as f64)),
        ]),
        EventKind::HttpConn { endpoint, status, bytes, events, disconnected } => Json::obj(vec![
            req,
            ("endpoint", Json::str(endpoint)),
            ("status", Json::num(*status as f64)),
            ("bytes", Json::num(*bytes as f64)),
            ("events", Json::num(*events as f64)),
            ("disconnected", Json::Bool(*disconnected)),
        ]),
    }
}

/// What [`validate_chrome_trace`] verified.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCheck {
    /// Events checked (metadata included).
    pub events: usize,
    /// Async begin events (`ph: "b"`).
    pub begins: usize,
    /// Async end events (`ph: "e"`) — equals `begins` in a valid trace.
    pub ends: usize,
    /// Complete spans (`ph: "X"`).
    pub completes: usize,
    /// Instant events (`ph: "i"`).
    pub instants: usize,
}

/// Validate a Chrome trace-event JSON document the way CI does: parse
/// strictly, require the `traceEvents` array, require `ph`/`name`/`pid`/
/// `tid` on every event, non-decreasing timestamps, non-negative `dur` on
/// complete spans, and matched `b`/`e` pairs per `(cat, id, name)` — the
/// every-admit-has-exactly-one-terminal invariant, restated over the file.
pub fn validate_chrome_trace(text: &str) -> Result<TraceCheck> {
    let doc = Json::parse(text).map_err(|e| anyhow::anyhow!("not valid JSON: {e}"))?;
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .context("missing 'traceEvents' array")?;
    let mut check = TraceCheck::default();
    let mut open: std::collections::BTreeMap<(String, u64, String), usize> =
        std::collections::BTreeMap::new();
    let mut last_ts = f64::NEG_INFINITY;
    for (i, ev) in events.iter().enumerate() {
        check.events += 1;
        let ph = ev.req_str("ph").with_context(|| format!("event {i}"))?;
        ev.req_str("name").with_context(|| format!("event {i}"))?;
        ev.req_f64("pid").with_context(|| format!("event {i}"))?;
        ev.req_f64("tid").with_context(|| format!("event {i}"))?;
        let ts = ev.req_f64("ts").with_context(|| format!("event {i}"))?;
        if ph == "M" {
            continue; // metadata carries no timeline meaning
        }
        if ts < last_ts {
            bail!("event {i}: timestamp regressed ({ts} < {last_ts})");
        }
        last_ts = ts;
        match ph {
            "b" | "e" => {
                let cat = ev.req_str("cat").with_context(|| format!("event {i}"))?;
                let id = ev.req_usize("id").with_context(|| format!("event {i}"))? as u64;
                let name = ev.req_str("name").unwrap();
                let key = (cat.to_string(), id, name.to_string());
                if ph == "b" {
                    check.begins += 1;
                    *open.entry(key).or_insert(0) += 1;
                } else {
                    check.ends += 1;
                    let n = open.get_mut(&key).map(|n| {
                        *n = n.saturating_sub(1);
                        *n
                    });
                    match n {
                        Some(_) if open[&key] == 0 => {
                            open.remove(&key);
                        }
                        Some(_) => {}
                        None => bail!(
                            "event {i}: 'e' without matching 'b' (cat={}, id={}, name={})",
                            key.0,
                            key.1,
                            key.2
                        ),
                    }
                }
            }
            "X" => {
                check.completes += 1;
                let dur = ev.req_f64("dur").with_context(|| format!("event {i}"))?;
                if dur < 0.0 {
                    bail!("event {i}: negative dur {dur}");
                }
            }
            "i" => check.instants += 1,
            other => bail!("event {i}: unsupported phase '{other}'"),
        }
    }
    if !open.is_empty() {
        let (cat, id, name) = open.keys().next().unwrap();
        bail!(
            "{} unmatched 'b' event(s) — first: cat={cat}, id={id}, name={name}",
            open.values().sum::<usize>()
        );
    }
    Ok(check)
}

/// Prometheus-style text snapshot of the final server counters — the
/// third export, for scrape-shaped consumers.
pub fn prometheus_text(r: &ServerReport) -> String {
    let mut s = String::new();
    let mut counter = |name: &str, help: &str, v: f64| {
        s.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"));
    };
    counter("mxmoe_requests_total", "Requests served", r.requests as f64);
    counter("mxmoe_tokens_total", "Tokens processed", r.tokens as f64);
    counter("mxmoe_expert_calls_total", "Expert tile executions", r.expert_calls as f64);
    counter("mxmoe_waves_total", "Grouped-dispatch waves", r.waves as f64);
    counter("mxmoe_replans_total", "Drift-triggered re-solves", r.replans as f64);
    counter("mxmoe_swaps_total", "Expert slots hot-swapped", r.swaps as f64);
    counter("mxmoe_stolen_batches_total", "Batches stolen between replicas", r.stolen_batches as f64);
    counter("mxmoe_admitted_total", "Requests admitted", r.admitted as f64);
    counter("mxmoe_cancelled_total", "Admitted requests cancelled", r.cancelled as f64);
    counter("mxmoe_failed_total", "Admitted requests failed", r.failed as f64);
    counter("mxmoe_decode_steps_total", "Mixed prefill/decode steps", r.decode_steps as f64);
    counter("mxmoe_generated_tokens_total", "Tokens generated and streamed", r.generated_tokens as f64);
    counter("mxmoe_generations_total", "Generations completed", r.generations as f64);
    counter(
        "mxmoe_http_connections_total",
        "HTTP connections accepted",
        r.http.connections as f64,
    );
    counter(
        "mxmoe_http_rejected_busy_total",
        "HTTP connections shed at the handler-pool bound",
        r.http.rejected_busy as f64,
    );
    counter(
        "mxmoe_http_disconnects_total",
        "HTTP client disconnects observed mid-response",
        r.http.disconnects as f64,
    );
    counter("mxmoe_http_sse_events_total", "SSE events streamed", r.http.sse_events as f64);
    counter("mxmoe_http_bytes_out_total", "HTTP response bytes written", r.http.bytes_out as f64);
    s.push_str("# HELP mxmoe_rejected_total Requests rejected at admission\n");
    s.push_str("# TYPE mxmoe_rejected_total counter\n");
    s.push_str(&format!(
        "mxmoe_rejected_total{{reason=\"queue_full\"}} {}\n",
        r.rejected_queue_full
    ));
    s.push_str(&format!("mxmoe_rejected_total{{reason=\"deadline\"}} {}\n", r.rejected_deadline));
    s.push_str(&format!("mxmoe_rejected_total{{reason=\"quota\"}} {}\n", r.rejected_quota));
    s.push_str(&format!("mxmoe_rejected_total{{reason=\"kv_exhausted\"}} {}\n", r.rejected_kv));
    s.push_str(
        "# HELP mxmoe_kv_preemptions_total Generations preempted for KV pages and replayed\n",
    );
    s.push_str("# TYPE mxmoe_kv_preemptions_total counter\n");
    s.push_str(&format!("mxmoe_kv_preemptions_total {}\n", r.kv_preemptions));
    s.push_str("# HELP mxmoe_qos_served_total Requests served per QoS class\n");
    s.push_str("# TYPE mxmoe_qos_served_total counter\n");
    for (name, v) in ["interactive", "standard", "batch"].iter().zip(r.qos_served) {
        s.push_str(&format!("mxmoe_qos_served_total{{class=\"{name}\"}} {v}\n"));
    }
    let mut gauge = |name: &str, help: &str, v: f64| {
        s.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"));
    };
    gauge("mxmoe_throughput_tps", "Tokens per second", r.throughput_tps);
    gauge("mxmoe_decode_tps", "Generated tokens per second", r.decode_tps);
    gauge("mxmoe_latency_p50_seconds", "Request latency p50", r.p50_latency_s);
    gauge("mxmoe_latency_p99_seconds", "Request latency p99", r.p99_latency_s);
    gauge("mxmoe_queue_wait_p50_seconds", "Queue wait p50", r.p50_queue_wait_s);
    gauge("mxmoe_wave_p50_seconds", "Wave wall-clock p50", r.p50_wave_s);
    gauge("mxmoe_step_p50_seconds", "Decode-step wall-clock p50", r.p50_step_s);
    gauge("mxmoe_padding_ratio", "Padding fraction of shipped rows", r.padding_ratio);
    gauge("mxmoe_wave_fill_ratio", "Useful fraction of wave rows", r.wave_fill_ratio);
    gauge("mxmoe_last_planned_fill", "Planner fill of last cut", r.last_planned_fill);
    gauge("mxmoe_last_drift", "Worst telemetry drift at last check", r.last_drift);
    gauge("mxmoe_generation", "Highest plan generation", r.generation as f64);
    gauge("mxmoe_replicas", "Engine replicas", r.replicas as f64);
    gauge("mxmoe_max_queue_depth", "Deepest admission queue", r.max_queue_depth as f64);
    gauge("mxmoe_kv_peak_tokens", "KV reservation high-water mark", r.kv_peak_tokens as f64);
    gauge("mxmoe_kv_used_tokens", "Tokens materialized in KV pages", r.kv_used_tokens as f64);
    gauge(
        "mxmoe_kv_shared_tokens",
        "Tokens served from shared prefix pages",
        r.kv_shared_tokens as f64,
    );
    gauge("mxmoe_kv_avg_bits", "Average bits per stored KV element", r.kv_avg_bits);
    gauge(
        "mxmoe_http_peak_connections",
        "Peak concurrently live HTTP connections",
        r.http.peak_connections as f64,
    );
    s.push_str("# HELP mxmoe_queue_wait_p99_seconds Queue wait p99 per priority\n");
    s.push_str("# TYPE mxmoe_queue_wait_p99_seconds gauge\n");
    for (name, v) in ["low", "normal", "high"].iter().zip(r.queue_wait_p99_by_priority) {
        s.push_str(&format!("mxmoe_queue_wait_p99_seconds{{priority=\"{name}\"}} {v}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::super::span::{Deadline, Outcome};
    use super::*;

    fn admit(ts: u64, req: u64) -> TraceEvent {
        TraceEvent {
            ts_us: ts,
            dur_us: 0,
            req,
            track: Track::Admission,
            kind: EventKind::Admitted { qos: "standard", priority: "normal", tokens: 8 },
        }
    }

    fn terminal(ts: u64, req: u64, outcome: Outcome) -> TraceEvent {
        TraceEvent {
            ts_us: ts,
            dur_us: 0,
            req,
            track: Track::Replica(0),
            kind: EventKind::Terminal {
                outcome,
                qos: "standard",
                queue_us: 5,
                compute_us: 10,
                stream_us: 0,
                generation: 0,
                deadline: Deadline::None,
                tokens: 8,
            },
        }
    }

    fn wave(ts: u64, dur: u64) -> TraceEvent {
        TraceEvent {
            ts_us: ts,
            dur_us: dur,
            req: 0,
            track: Track::Replica(0),
            kind: EventKind::Wave { scheme: "fp16", tile_m: 16, items: 2, rows: 20, padded: 32 },
        }
    }

    fn sample_log() -> TraceLog {
        TraceLog::merge(vec![
            (vec![admit(10, 1), admit(12, 2)], 0),
            (vec![terminal(40, 1, Outcome::Done), terminal(55, 2, Outcome::Cancelled)], 0),
            (vec![wave(20, 9)], 0),
        ])
    }

    #[test]
    fn merge_sorts_and_counts() {
        let log = sample_log();
        assert_eq!(log.len(), 5);
        for w in log.events.windows(2) {
            assert!(w[0].ts_us <= w[1].ts_us);
        }
        assert_eq!(log.admitted_ids(), vec![1, 2]);
        assert_eq!(log.terminals().len(), 2);
    }

    #[test]
    fn chrome_trace_round_trips_through_the_validator() {
        let log = sample_log();
        let text = log.chrome_trace().dump();
        let check = validate_chrome_trace(&text).expect("valid trace");
        assert_eq!(check.begins, 2);
        assert_eq!(check.ends, 2);
        assert_eq!(check.completes, 1);
    }

    #[test]
    fn validator_rejects_unmatched_begin() {
        let log = TraceLog::merge(vec![(vec![admit(10, 1)], 0)]);
        let err = validate_chrome_trace(&log.chrome_trace().dump()).unwrap_err();
        assert!(err.to_string().contains("unmatched 'b'"), "{err}");
    }

    #[test]
    fn validator_rejects_end_without_begin() {
        let log = TraceLog::merge(vec![(vec![terminal(10, 1, Outcome::Done)], 0)]);
        let err = validate_chrome_trace(&log.chrome_trace().dump()).unwrap_err();
        assert!(err.to_string().contains("without matching 'b'"), "{err}");
    }

    #[test]
    fn validator_rejects_regressed_timestamps() {
        // hand-build a document with a regressed ts
        let text = r#"{"traceEvents":[
            {"ph":"i","s":"t","name":"a","pid":1,"tid":1,"ts":100},
            {"ph":"i","s":"t","name":"b","pid":1,"tid":1,"ts":50}
        ]}"#;
        let err = validate_chrome_trace(text).unwrap_err();
        assert!(err.to_string().contains("regressed"), "{err}");
    }

    #[test]
    fn validator_rejects_garbage_and_missing_fields() {
        assert!(validate_chrome_trace("not json").is_err());
        assert!(validate_chrome_trace(r#"{"events":[]}"#).is_err());
        assert!(validate_chrome_trace(
            r#"{"traceEvents":[{"ph":"X","name":"w","pid":1,"tid":1,"ts":1}]}"#
        )
        .is_err(), "X without dur");
    }

    #[test]
    fn jsonl_lines_parse_back() {
        let log = sample_log();
        let text = log.jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), log.len());
        for line in lines {
            let v = Json::parse(line).expect("valid jsonl line");
            assert!(v.get("ts_us").is_some());
            assert!(v.get("event").is_some());
        }
    }
}
