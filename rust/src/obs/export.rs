//! Trace export: Chrome trace-event JSON (Perfetto-loadable), JSONL
//! structured events, a Prometheus text snapshot (with a conformance
//! linter), the `/v1/status` JSON and `/debug` HTML renderers of the
//! fleet observatory, and the validator CI runs over emitted traces
//! (DESIGN.md §Observability, §Fleet-Observatory).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::metrics::{slo_class_name, ServerReport};
use crate::ser::{Json, JsonWriter};

use super::provenance::PlanRecord;
use super::span::{EventKind, Track, TraceEvent};
use super::timeseries::{ObservatorySnapshot, Point};

/// The merged, time-sorted event log of one serving run: every collector's
/// ring drained into one timeline at shutdown.
#[derive(Clone, Debug, Default)]
pub struct TraceLog {
    /// Events sorted by timestamp (admits before terminals at equal ts).
    pub events: Vec<TraceEvent>,
    /// Events overwritten in bounded rings before the drain (0 = the log
    /// is complete).
    pub dropped: usize,
}

impl TraceLog {
    pub fn empty() -> TraceLog {
        TraceLog::default()
    }

    /// Merge drained collector rings into one sorted timeline. Sorting is
    /// by timestamp with lifecycle tie-breaks (an admit sorts before a
    /// terminal recorded in the same microsecond), so the exported Chrome
    /// trace is monotonic and its async begin/end pairs nest.
    pub fn merge(parts: Vec<(Vec<TraceEvent>, usize)>) -> TraceLog {
        let mut events = Vec::new();
        let mut dropped = 0;
        for (evs, d) in parts {
            events.extend(evs);
            dropped += d;
        }
        events.sort_by_key(|e| (e.ts_us, lifecycle_rank(&e.kind), e.req));
        TraceLog { events, dropped }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Request ids admitted in this log.
    pub fn admitted_ids(&self) -> Vec<u64> {
        self.events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::Admitted { .. }))
            .map(|e| e.req)
            .collect()
    }

    /// Terminal events per request id: `(id, outcome)` in time order.
    pub fn terminals(&self) -> Vec<(u64, super::span::Outcome)> {
        self.events
            .iter()
            .filter_map(|e| match e.kind {
                EventKind::Terminal { outcome, .. } => Some((e.req, outcome)),
                _ => None,
            })
            .collect()
    }

    /// The full log as a Chrome trace-event JSON document
    /// (<https://ui.perfetto.dev> loads it directly). Request lifecycles
    /// are nestable async `b`/`e` pairs keyed by request id; waves, decode
    /// steps and replan phases are complete (`X`) spans on their thread's
    /// track; rejections and routing decisions are instants.
    pub fn chrome_trace(&self) -> Json {
        let mut out = Vec::new();
        // thread-name metadata first (ts 0 keeps the stream monotonic)
        let mut tracks: Vec<Track> = Vec::new();
        for e in &self.events {
            if !tracks.contains(&e.track) {
                tracks.push(e.track);
            }
        }
        tracks.sort_by_key(Track::tid);
        out.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("process_name")),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(0.0)),
            ("ts", Json::num(0.0)),
            ("args", Json::obj(vec![("name", Json::str("mxmoe"))])),
        ]));
        for t in &tracks {
            out.push(Json::obj(vec![
                ("ph", Json::str("M")),
                ("name", Json::str("thread_name")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(t.tid() as f64)),
                ("ts", Json::num(0.0)),
                ("args", Json::obj(vec![("name", Json::str(&t.name()))])),
            ]));
        }
        for e in &self.events {
            let mut fields = vec![
                ("name", Json::str(e.kind.name())),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(e.track.tid() as f64)),
                ("ts", Json::num(e.ts_us as f64)),
                ("args", event_args(e)),
            ];
            match &e.kind {
                EventKind::Admitted { .. } => {
                    fields.push(("ph", Json::str("b")));
                    fields.push(("cat", Json::str("request")));
                    fields.push(("id", Json::num(e.req as f64)));
                }
                EventKind::Terminal { .. } => {
                    fields.push(("ph", Json::str("e")));
                    fields.push(("cat", Json::str("request")));
                    fields.push(("id", Json::num(e.req as f64)));
                }
                EventKind::Rejected { .. }
                | EventKind::BatchCut { .. }
                | EventKind::Routed { .. }
                | EventKind::KvPreempt { .. } => {
                    fields.push(("ph", Json::str("i")));
                    fields.push(("s", Json::str("t")));
                }
                EventKind::Wave { .. }
                | EventKind::DecodeStep { .. }
                | EventKind::ReplanSolve { .. }
                | EventKind::SwapStage { .. }
                | EventKind::SwapInstall { .. }
                | EventKind::HttpConn { .. } => {
                    fields.push(("ph", Json::str("X")));
                    fields.push(("dur", Json::num(e.dur_us as f64)));
                }
            }
            out.push(Json::obj(fields));
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(out)),
            ("displayTimeUnit", Json::str("ms")),
            ("otherData", Json::obj(vec![("droppedEvents", Json::num(self.dropped as f64))])),
        ])
    }

    pub fn write_chrome_trace(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.chrome_trace().dump())
            .with_context(|| format!("write chrome trace {path:?}"))
    }

    /// One structured JSON object per line — the machine-diffable log.
    pub fn jsonl(&self) -> String {
        let mut s = String::new();
        for e in &self.events {
            let line = Json::obj(vec![
                ("ts_us", Json::num(e.ts_us as f64)),
                ("dur_us", Json::num(e.dur_us as f64)),
                ("req", Json::num(e.req as f64)),
                ("track", Json::str(&e.track.name())),
                ("event", Json::str(e.kind.name())),
                ("args", event_args(e)),
            ]);
            s.push_str(&line.dump());
            s.push('\n');
        }
        s
    }

    pub fn write_jsonl(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.jsonl()).with_context(|| format!("write jsonl {path:?}"))
    }
}

/// Sort rank at equal timestamps: admits open before anything else; a
/// terminal closes after everything else.
fn lifecycle_rank(kind: &EventKind) -> u8 {
    match kind {
        EventKind::Admitted { .. } => 0,
        EventKind::Terminal { .. } => 2,
        _ => 1,
    }
}

/// Kind-specific argument object (shared by the Chrome and JSONL exports).
fn event_args(e: &TraceEvent) -> Json {
    let req = ("req", Json::num(e.req as f64));
    match &e.kind {
        EventKind::Admitted { qos, priority, tokens } => Json::obj(vec![
            req,
            ("qos", Json::str(qos)),
            ("priority", Json::str(priority)),
            ("tokens", Json::num(*tokens as f64)),
        ]),
        EventKind::Rejected { reason } => Json::obj(vec![req, ("reason", Json::str(reason))]),
        EventKind::BatchCut { seqs, tokens, fill } => Json::obj(vec![
            ("seqs", Json::num(*seqs as f64)),
            ("tokens", Json::num(*tokens as f64)),
            ("fill", Json::num(*fill)),
        ]),
        EventKind::Routed { replica } => {
            Json::obj(vec![req, ("replica", Json::num(*replica as f64))])
        }
        EventKind::Terminal {
            outcome,
            qos,
            queue_us,
            compute_us,
            stream_us,
            generation,
            deadline,
            tokens,
        } => Json::obj(vec![
            req,
            ("outcome", Json::str(outcome.name())),
            ("qos", Json::str(qos)),
            ("queue_us", Json::num(*queue_us as f64)),
            ("compute_us", Json::num(*compute_us as f64)),
            ("stream_us", Json::num(*stream_us as f64)),
            ("generation", Json::num(*generation as f64)),
            ("deadline", Json::str(deadline.name())),
            ("tokens", Json::num(*tokens as f64)),
        ]),
        EventKind::Wave { scheme, tile_m, items, rows, padded } => Json::obj(vec![
            ("scheme", Json::str(scheme)),
            ("tile_m", Json::num(*tile_m as f64)),
            ("items", Json::num(*items as f64)),
            ("rows", Json::num(*rows as f64)),
            ("padded", Json::num(*padded as f64)),
        ]),
        EventKind::DecodeStep {
            rows,
            prefill_rows,
            decode_rows,
            tokens,
            kv_reserved,
            kv_used,
            kv_budget,
        } => Json::obj(vec![
            ("rows", Json::num(*rows as f64)),
            ("prefill_rows", Json::num(*prefill_rows as f64)),
            ("decode_rows", Json::num(*decode_rows as f64)),
            ("tokens", Json::num(*tokens as f64)),
            ("kv_reserved", Json::num(*kv_reserved as f64)),
            ("kv_used", Json::num(*kv_used as f64)),
            ("kv_budget", Json::num(*kv_budget as f64)),
        ]),
        EventKind::KvPreempt { kv_reserved, kv_budget } => Json::obj(vec![
            ("kv_reserved", Json::num(*kv_reserved as f64)),
            ("kv_budget", Json::num(*kv_budget as f64)),
        ]),
        EventKind::ReplanSolve { drift, changes } => Json::obj(vec![
            ("drift", Json::num(*drift)),
            ("changes", Json::num(*changes as f64)),
        ]),
        EventKind::SwapStage { changes } => {
            Json::obj(vec![("changes", Json::num(*changes as f64))])
        }
        EventKind::SwapInstall { swapped, generation } => Json::obj(vec![
            ("swapped", Json::num(*swapped as f64)),
            ("generation", Json::num(*generation as f64)),
        ]),
        EventKind::HttpConn { endpoint, status, bytes, events, disconnected } => Json::obj(vec![
            req,
            ("endpoint", Json::str(endpoint)),
            ("status", Json::num(*status as f64)),
            ("bytes", Json::num(*bytes as f64)),
            ("events", Json::num(*events as f64)),
            ("disconnected", Json::Bool(*disconnected)),
        ]),
    }
}

/// What [`validate_chrome_trace`] verified.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCheck {
    /// Events checked (metadata included).
    pub events: usize,
    /// Async begin events (`ph: "b"`).
    pub begins: usize,
    /// Async end events (`ph: "e"`) — equals `begins` in a valid trace.
    pub ends: usize,
    /// Complete spans (`ph: "X"`).
    pub completes: usize,
    /// Instant events (`ph: "i"`).
    pub instants: usize,
}

/// Validate a Chrome trace-event JSON document the way CI does: parse
/// strictly, require the `traceEvents` array, require `ph`/`name`/`pid`/
/// `tid` on every event, non-decreasing timestamps, non-negative `dur` on
/// complete spans, and matched `b`/`e` pairs per `(cat, id, name)` — the
/// every-admit-has-exactly-one-terminal invariant, restated over the file.
pub fn validate_chrome_trace(text: &str) -> Result<TraceCheck> {
    let doc = Json::parse(text).map_err(|e| anyhow::anyhow!("not valid JSON: {e}"))?;
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .context("missing 'traceEvents' array")?;
    let mut check = TraceCheck::default();
    let mut open: std::collections::BTreeMap<(String, u64, String), usize> =
        std::collections::BTreeMap::new();
    let mut last_ts = f64::NEG_INFINITY;
    for (i, ev) in events.iter().enumerate() {
        check.events += 1;
        let ph = ev.req_str("ph").with_context(|| format!("event {i}"))?;
        ev.req_str("name").with_context(|| format!("event {i}"))?;
        ev.req_f64("pid").with_context(|| format!("event {i}"))?;
        ev.req_f64("tid").with_context(|| format!("event {i}"))?;
        let ts = ev.req_f64("ts").with_context(|| format!("event {i}"))?;
        if ph == "M" {
            continue; // metadata carries no timeline meaning
        }
        if ts < last_ts {
            bail!("event {i}: timestamp regressed ({ts} < {last_ts})");
        }
        last_ts = ts;
        match ph {
            "b" | "e" => {
                let cat = ev.req_str("cat").with_context(|| format!("event {i}"))?;
                let id = ev.req_usize("id").with_context(|| format!("event {i}"))? as u64;
                let name = ev.req_str("name").unwrap();
                let key = (cat.to_string(), id, name.to_string());
                if ph == "b" {
                    check.begins += 1;
                    *open.entry(key).or_insert(0) += 1;
                } else {
                    check.ends += 1;
                    let n = open.get_mut(&key).map(|n| {
                        *n = n.saturating_sub(1);
                        *n
                    });
                    match n {
                        Some(_) if open[&key] == 0 => {
                            open.remove(&key);
                        }
                        Some(_) => {}
                        None => bail!(
                            "event {i}: 'e' without matching 'b' (cat={}, id={}, name={})",
                            key.0,
                            key.1,
                            key.2
                        ),
                    }
                }
            }
            "X" => {
                check.completes += 1;
                let dur = ev.req_f64("dur").with_context(|| format!("event {i}"))?;
                if dur < 0.0 {
                    bail!("event {i}: negative dur {dur}");
                }
            }
            "i" => check.instants += 1,
            other => bail!("event {i}: unsupported phase '{other}'"),
        }
    }
    if !open.is_empty() {
        let (cat, id, name) = open.keys().next().unwrap();
        bail!(
            "{} unmatched 'b' event(s) — first: cat={cat}, id={id}, name={name}",
            open.values().sum::<usize>()
        );
    }
    Ok(check)
}

/// Escape a Prometheus label value: backslash, double-quote and newline
/// are the three characters the text exposition format requires escaping
/// (everything else passes through verbatim).
pub fn prom_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Prometheus-style text snapshot of the final server counters — the
/// third export, for scrape-shaped consumers.
pub fn prometheus_text(r: &ServerReport) -> String {
    prometheus_text_with(r, None)
}

/// [`prometheus_text`] plus the observatory's sampled histograms rendered
/// as native Prometheus histogram families (cumulative `_bucket{le=...}`
/// samples, `_sum`, `_count`). Every family carries `# HELP`/`# TYPE`,
/// label values are escaped, and non-finite gauges are suppressed rather
/// than emitted as `NaN` — [`lint_prometheus`] holds this to account.
pub fn prometheus_text_with(r: &ServerReport, obs: Option<&ObservatorySnapshot>) -> String {
    let mut s = String::new();
    let mut counter = |name: &str, help: &str, v: f64| {
        s.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"));
    };
    counter("mxmoe_requests_total", "Requests served", r.requests as f64);
    counter("mxmoe_tokens_total", "Tokens processed", r.tokens as f64);
    counter("mxmoe_expert_calls_total", "Expert tile executions", r.expert_calls as f64);
    counter("mxmoe_waves_total", "Grouped-dispatch waves", r.waves as f64);
    counter("mxmoe_replans_total", "Drift-triggered re-solves", r.replans as f64);
    counter("mxmoe_swaps_total", "Expert slots hot-swapped", r.swaps as f64);
    counter("mxmoe_stolen_batches_total", "Batches stolen between replicas", r.stolen_batches as f64);
    counter("mxmoe_admitted_total", "Requests admitted", r.admitted as f64);
    counter("mxmoe_cancelled_total", "Admitted requests cancelled", r.cancelled as f64);
    counter("mxmoe_failed_total", "Admitted requests failed", r.failed as f64);
    counter("mxmoe_decode_steps_total", "Mixed prefill/decode steps", r.decode_steps as f64);
    counter("mxmoe_generated_tokens_total", "Tokens generated and streamed", r.generated_tokens as f64);
    counter("mxmoe_generations_total", "Generations completed", r.generations as f64);
    counter(
        "mxmoe_http_connections_total",
        "HTTP connections accepted",
        r.http.connections as f64,
    );
    counter(
        "mxmoe_http_rejected_busy_total",
        "HTTP connections shed at the handler-pool bound",
        r.http.rejected_busy as f64,
    );
    counter(
        "mxmoe_http_disconnects_total",
        "HTTP client disconnects observed mid-response",
        r.http.disconnects as f64,
    );
    counter("mxmoe_http_sse_events_total", "SSE events streamed", r.http.sse_events as f64);
    counter("mxmoe_http_bytes_out_total", "HTTP response bytes written", r.http.bytes_out as f64);
    s.push_str("# HELP mxmoe_rejected_total Requests rejected at admission\n");
    s.push_str("# TYPE mxmoe_rejected_total counter\n");
    for (reason, v) in [
        ("queue_full", r.rejected_queue_full),
        ("deadline", r.rejected_deadline),
        ("quota", r.rejected_quota),
        ("kv_exhausted", r.rejected_kv),
    ] {
        s.push_str(&format!("mxmoe_rejected_total{{reason=\"{}\"}} {v}\n", prom_label(reason)));
    }
    s.push_str(
        "# HELP mxmoe_kv_preemptions_total Generations preempted for KV pages and replayed\n",
    );
    s.push_str("# TYPE mxmoe_kv_preemptions_total counter\n");
    s.push_str(&format!("mxmoe_kv_preemptions_total {}\n", r.kv_preemptions));
    s.push_str("# HELP mxmoe_qos_served_total Requests served per QoS class\n");
    s.push_str("# TYPE mxmoe_qos_served_total counter\n");
    for (name, v) in ["interactive", "standard", "batch"].iter().zip(r.qos_served) {
        s.push_str(&format!("mxmoe_qos_served_total{{class=\"{}\"}} {v}\n", prom_label(name)));
    }
    // Non-finite gauges are suppressed (family and sample) instead of
    // being exposed as `NaN`, which scrapers reject.
    let mut gauge = |name: &str, help: &str, v: f64| {
        if !v.is_finite() {
            return;
        }
        s.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"));
    };
    gauge("mxmoe_throughput_tps", "Tokens per second", r.throughput_tps);
    gauge("mxmoe_decode_tps", "Generated tokens per second", r.decode_tps);
    gauge("mxmoe_latency_p50_seconds", "Request latency p50", r.p50_latency_s);
    gauge("mxmoe_latency_p99_seconds", "Request latency p99", r.p99_latency_s);
    gauge("mxmoe_queue_wait_p50_seconds", "Queue wait p50", r.p50_queue_wait_s);
    gauge("mxmoe_wave_p50_seconds", "Wave wall-clock p50", r.p50_wave_s);
    gauge("mxmoe_step_p50_seconds", "Decode-step wall-clock p50", r.p50_step_s);
    gauge("mxmoe_padding_ratio", "Padding fraction of shipped rows", r.padding_ratio);
    gauge("mxmoe_wave_fill_ratio", "Useful fraction of wave rows", r.wave_fill_ratio);
    gauge("mxmoe_last_planned_fill", "Planner fill of last cut", r.last_planned_fill);
    gauge("mxmoe_last_drift", "Worst telemetry drift at last check", r.last_drift);
    gauge("mxmoe_generation", "Highest plan generation", r.generation as f64);
    gauge("mxmoe_replicas", "Engine replicas", r.replicas as f64);
    gauge("mxmoe_max_queue_depth", "Deepest admission queue", r.max_queue_depth as f64);
    gauge("mxmoe_kv_peak_tokens", "KV reservation high-water mark", r.kv_peak_tokens as f64);
    gauge("mxmoe_kv_used_tokens", "Tokens materialized in KV pages", r.kv_used_tokens as f64);
    gauge(
        "mxmoe_kv_shared_tokens",
        "Tokens served from shared prefix pages",
        r.kv_shared_tokens as f64,
    );
    gauge(
        "mxmoe_kv_budget_tokens",
        "KV page-pool capacity in tokens",
        r.kv_budget_tokens as f64,
    );
    gauge("mxmoe_kv_avg_bits", "Average bits per stored KV element", r.kv_avg_bits);
    gauge(
        "mxmoe_http_peak_connections",
        "Peak concurrently live HTTP connections",
        r.http.peak_connections as f64,
    );
    s.push_str("# HELP mxmoe_queue_wait_p99_seconds Queue wait p99 per priority\n");
    s.push_str("# TYPE mxmoe_queue_wait_p99_seconds gauge\n");
    for (name, v) in ["low", "normal", "high"].iter().zip(r.queue_wait_p99_by_priority) {
        if v.is_finite() {
            s.push_str(&format!(
                "mxmoe_queue_wait_p99_seconds{{priority=\"{}\"}} {v}\n",
                prom_label(name)
            ));
        }
    }
    if let Some(snap) = obs {
        for h in &snap.histograms {
            let name = format!("mxmoe_{}", h.name);
            s.push_str(&format!(
                "# HELP {name} Sampled distribution recorded by the observatory\n"
            ));
            s.push_str(&format!("# TYPE {name} histogram\n"));
            let mut cum = 0u64;
            for (b, c) in h.bounds.iter().zip(&h.counts) {
                cum += c;
                s.push_str(&format!("{name}_bucket{{le=\"{b}\"}} {cum}\n"));
            }
            s.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
            if h.sum.is_finite() {
                s.push_str(&format!("{name}_sum {}\n", h.sum));
            } else {
                s.push_str(&format!("{name}_sum 0\n"));
            }
            s.push_str(&format!("{name}_count {}\n", h.count));
        }
    }
    s
}

/// Lint a Prometheus text exposition the way a strict scraper would:
/// every sample's family must carry `# HELP` and `# TYPE` (HELP first),
/// counter names must end in `_total`, sample values must parse and must
/// not be `NaN`, label sets must follow the `key="value"` grammar with
/// only `\\`, `\"` and `\n` escapes, and histogram families must expose
/// monotone cumulative buckets ending in `le="+Inf"` plus `_sum`/`_count`.
pub fn lint_prometheus(text: &str) -> Result<()> {
    use std::collections::{BTreeMap, BTreeSet};
    #[derive(Default)]
    struct HistState {
        inf: bool,
        sum: bool,
        count: bool,
        last_cum: f64,
    }
    let mut helps: BTreeSet<String> = BTreeSet::new();
    let mut types: BTreeMap<String, &'static str> = BTreeMap::new();
    let mut hists: BTreeMap<String, HistState> = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        let n = i + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest
                .split_whitespace()
                .next()
                .with_context(|| format!("line {n}: HELP without a metric name"))?;
            if rest.len() <= name.len() + 1 {
                bail!("line {n}: HELP without help text for '{name}'");
            }
            if !helps.insert(name.to_string()) {
                bail!("line {n}: duplicate HELP for '{name}'");
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name =
                it.next().with_context(|| format!("line {n}: TYPE without a metric name"))?;
            let ty = match it.next() {
                Some("counter") => "counter",
                Some("gauge") => "gauge",
                Some("histogram") => {
                    hists.entry(name.to_string()).or_default();
                    "histogram"
                }
                other => bail!("line {n}: unsupported TYPE {other:?} for '{name}'"),
            };
            if !helps.contains(name) {
                bail!("line {n}: TYPE for '{name}' precedes its HELP");
            }
            if types.insert(name.to_string(), ty).is_some() {
                bail!("line {n}: duplicate TYPE for '{name}'");
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // free-form comments are legal
        }
        let (series, value) =
            line.rsplit_once(' ').with_context(|| format!("line {n}: sample without a value"))?;
        let v: f64 =
            value.parse().with_context(|| format!("line {n}: unparseable value '{value}'"))?;
        if v.is_nan() {
            bail!("line {n}: NaN sample value for '{series}'");
        }
        let (name, labels) = match series.split_once('{') {
            Some((base, rest)) => {
                let body = rest
                    .strip_suffix('}')
                    .with_context(|| format!("line {n}: unterminated label set"))?;
                (base, Some(body))
            }
            None => (series, None),
        };
        if !valid_metric_name(name) {
            bail!("line {n}: invalid metric name '{name}'");
        }
        if let Some(body) = labels {
            lint_labels(body, n)?;
        }
        let hist_part = ["_bucket", "_sum", "_count"].iter().find_map(|suf| {
            name.strip_suffix(suf)
                .filter(|base| types.get(*base).copied() == Some("histogram"))
                .map(|base| (base, *suf))
        });
        match hist_part {
            Some((base, "_bucket")) => {
                let le = labels
                    .and_then(|b| b.strip_prefix("le=\""))
                    .and_then(|b| b.strip_suffix('"'))
                    .with_context(|| format!("line {n}: histogram bucket without an le label"))?;
                let st = hists.get_mut(base).unwrap();
                if v + 1e-9 < st.last_cum {
                    bail!("line {n}: cumulative bucket counts regress for '{base}'");
                }
                st.last_cum = v;
                if le == "+Inf" {
                    st.inf = true;
                } else {
                    le.parse::<f64>()
                        .with_context(|| format!("line {n}: unparseable le bound '{le}'"))?;
                }
            }
            Some((base, "_sum")) => hists.get_mut(base).unwrap().sum = true,
            Some((base, _)) => hists.get_mut(base).unwrap().count = true,
            None => {
                let ty = types
                    .get(name)
                    .with_context(|| format!("line {n}: sample '{name}' has no # TYPE"))?;
                if !helps.contains(name) {
                    bail!("line {n}: sample '{name}' has no # HELP");
                }
                if *ty == "counter" && !name.ends_with("_total") {
                    bail!("line {n}: counter '{name}' does not end in _total");
                }
                if *ty == "histogram" {
                    bail!("line {n}: bare sample for histogram family '{name}'");
                }
            }
        }
    }
    for (name, st) in &hists {
        if !st.inf {
            bail!("histogram '{name}' lacks an le=\"+Inf\" bucket");
        }
        if !st.sum || !st.count {
            bail!("histogram '{name}' lacks _sum/_count samples");
        }
    }
    Ok(())
}

fn valid_metric_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Check one `key="value",...` label-set body against the exposition
/// grammar (shared by [`lint_prometheus`]).
fn lint_labels(body: &str, n: usize) -> Result<()> {
    let mut rest = body;
    loop {
        let eq = rest.find('=').with_context(|| format!("line {n}: label without '='"))?;
        let key = &rest[..eq];
        if !valid_metric_name(key) {
            bail!("line {n}: invalid label name '{key}'");
        }
        rest = &rest[eq + 1..];
        if !rest.starts_with('"') {
            bail!("line {n}: label value for '{key}' is not quoted");
        }
        let mut end = None;
        let mut escaped = false;
        for (i, c) in rest.char_indices().skip(1) {
            if escaped {
                if !matches!(c, '\\' | '"' | 'n') {
                    bail!("line {n}: unsupported escape '\\{c}' in label '{key}'");
                }
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                end = Some(i);
                break;
            }
        }
        let end = end.with_context(|| format!("line {n}: unterminated value for '{key}'"))?;
        rest = &rest[end + 1..];
        if rest.is_empty() {
            return Ok(());
        }
        rest = rest
            .strip_prefix(',')
            .with_context(|| format!("line {n}: expected ',' between labels"))?;
    }
}

/// The `GET /v1/status` document: a versioned JSON snapshot of the live
/// server report, every recorded time series (as `[t_s, value]` pairs),
/// the sampled histograms, and the plan-provenance ledger. Only the
/// newest plan carries its full per-slot decision list; older entries
/// are summarized (slots/changed counts) to bound the payload.
pub fn status_json(
    r: &ServerReport,
    obs: Option<&ObservatorySnapshot>,
    plans: &[PlanRecord],
) -> String {
    let mut w = JsonWriter::new();
    w.begin_obj();
    w.field_str("version", "mxmoe-status-v1");
    w.key("report");
    w.begin_obj();
    w.field_u64("requests", r.requests as u64);
    w.field_u64("tokens", r.tokens as u64);
    w.field_u64("admitted", r.admitted as u64);
    w.field_u64("rejected_queue_full", r.rejected_queue_full as u64);
    w.field_u64("rejected_deadline", r.rejected_deadline as u64);
    w.field_u64("rejected_quota", r.rejected_quota as u64);
    w.field_u64("rejected_kv", r.rejected_kv as u64);
    w.field_u64("cancelled", r.cancelled as u64);
    w.field_u64("failed", r.failed as u64);
    w.field_u64("generated_tokens", r.generated_tokens as u64);
    w.field_u64("generations", r.generations as u64);
    w.field_u64("replans", r.replans as u64);
    w.field_u64("swaps", r.swaps as u64);
    w.field_u64("kv_preemptions", r.kv_preemptions as u64);
    w.field_u64("generation", r.generation);
    w.field_u64("replicas", r.replicas as u64);
    w.field_f64("throughput_tps", r.throughput_tps);
    w.field_f64("decode_tps", r.decode_tps);
    w.field_u64("kv_used_tokens", r.kv_used_tokens as u64);
    w.field_u64("kv_shared_tokens", r.kv_shared_tokens as u64);
    w.field_u64("kv_budget_tokens", r.kv_budget_tokens as u64);
    w.field_f64("kv_avg_bits", r.kv_avg_bits);
    w.key("qos_served");
    w.begin_arr();
    for v in r.qos_served {
        w.u64_val(v as u64);
    }
    w.end_arr();
    w.key("slo");
    w.begin_arr();
    for (i, c) in r.slo_by_class.iter().enumerate() {
        w.begin_obj();
        w.field_str("class", slo_class_name(i));
        w.field_u64("served", c.served as u64);
        w.field_u64("deadline_hit", c.deadline_hit as u64);
        w.field_u64("deadline_miss", c.deadline_miss as u64);
        w.field_f64("hit_rate", c.hit_rate());
        w.end_obj();
    }
    w.end_arr();
    w.end_obj();
    w.key("series");
    w.begin_arr();
    if let Some(snap) = obs {
        for sr in &snap.series {
            w.begin_obj();
            w.field_str("name", &sr.name);
            w.field_str("kind", sr.kind.name());
            w.field_u64("pushed", sr.pushed);
            w.field_u64("total", sr.total);
            w.key("points");
            w.begin_arr();
            for p in &sr.points {
                w.begin_arr();
                w.f64_val(p.t_s);
                w.f64_val(p.v);
                w.end_arr();
            }
            w.end_arr();
            w.end_obj();
        }
    }
    w.end_arr();
    w.key("histograms");
    w.begin_arr();
    if let Some(snap) = obs {
        for h in &snap.histograms {
            w.begin_obj();
            w.field_str("name", &h.name);
            w.key("bounds");
            w.begin_arr();
            for b in &h.bounds {
                w.f64_val(*b);
            }
            w.end_arr();
            w.key("counts");
            w.begin_arr();
            for c in &h.counts {
                w.u64_val(*c);
            }
            w.end_arr();
            w.field_f64("sum", h.sum);
            w.field_u64("count", h.count);
            w.end_obj();
        }
    }
    w.end_arr();
    w.key("plans");
    w.begin_arr();
    for (i, p) in plans.iter().enumerate() {
        w.begin_obj();
        w.field_u64("replica", p.replica as u64);
        w.field_u64("generation", p.generation);
        w.field_f64("at_s", p.at_s);
        w.field_str("trigger", p.trigger.name());
        w.field_f64("drift", p.drift);
        w.field_f64("r", p.r);
        w.field_f64("bits_before", p.bits_before);
        w.field_f64("bits_after", p.bits_after);
        w.field_u64("slots", p.decisions.len() as u64);
        w.field_u64("changed", p.changed() as u64);
        if i + 1 == plans.len() {
            w.key("decisions");
            w.begin_arr();
            for d in &p.decisions {
                w.begin_obj();
                w.field_u64("layer", d.layer as u64);
                w.field_u64("expert", d.expert as u64);
                w.field_bool("shared", d.shared);
                w.field_str("scheme", d.scheme.name());
                w.field_str("quant", &d.quant);
                w.key("prev");
                match d.prev {
                    Some(prev) => w.str_val(prev.name()),
                    None => w.null_val(),
                }
                w.field_bool("changed", d.changed);
                w.field_f64("sensitivity", d.sensitivity);
                w.field_f64("freq", d.freq);
                w.field_f64("bits", d.bits);
                w.key("speed_rows_per_s");
                match d.speed_rows_per_s {
                    Some(v) => w.f64_val(v),
                    None => w.null_val(),
                }
                w.end_obj();
            }
            w.end_arr();
        }
        w.end_obj();
    }
    w.end_arr();
    w.end_obj();
    w.finish().to_string()
}

/// Escape text for HTML element/attribute context.
fn html_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '&' => out.push_str("&amp;"),
            '<' => out.push_str("&lt;"),
            '>' => out.push_str("&gt;"),
            '"' => out.push_str("&quot;"),
            _ => out.push(c),
        }
    }
    out
}

/// An inline SVG sparkline over a series' points — no external assets,
/// no scripts; the dashboard stays a single self-contained document.
fn sparkline_svg(points: &[Point]) -> String {
    const W: f64 = 140.0;
    const H: f64 = 28.0;
    if points.is_empty() {
        return "<span class=\"dim\">no samples</span>".to_string();
    }
    if points.len() == 1 {
        return format!(
            "<svg width=\"{W}\" height=\"{H}\"><circle cx=\"3\" cy=\"{:.1}\" r=\"1.5\" \
             fill=\"#7ee0a3\"/></svg>",
            H / 2.0
        );
    }
    let t0 = points[0].t_s;
    let dt = (points[points.len() - 1].t_s - t0).max(1e-9);
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for p in points {
        lo = lo.min(p.v);
        hi = hi.max(p.v);
    }
    if !(hi - lo).is_finite() || hi - lo < 1e-12 {
        lo -= 0.5;
        hi += 0.5;
    }
    let mut path = String::new();
    for p in points {
        let x = 2.0 + (p.t_s - t0) / dt * (W - 4.0);
        let y = H - 2.0 - (p.v - lo) / (hi - lo) * (H - 4.0);
        if !path.is_empty() {
            path.push(' ');
        }
        path.push_str(&format!("{x:.1},{y:.1}"));
    }
    format!(
        "<svg width=\"{W}\" height=\"{H}\"><polyline fill=\"none\" stroke=\"#7ee0a3\" \
         stroke-width=\"1.2\" points=\"{path}\"/></svg>"
    )
}

/// Inline SVG bucket bars for a sampled histogram.
fn bars_svg(counts: &[u64]) -> String {
    const H: f64 = 28.0;
    const BW: f64 = 7.0;
    let max = counts.iter().copied().max().unwrap_or(0).max(1) as f64;
    let w = BW * counts.len() as f64;
    let mut bars = String::new();
    for (i, c) in counts.iter().enumerate() {
        let h = (*c as f64 / max) * (H - 2.0);
        bars.push_str(&format!(
            "<rect x=\"{:.1}\" y=\"{:.1}\" width=\"{:.1}\" height=\"{h:.1}\" fill=\"#8ab4f8\"/>",
            i as f64 * BW,
            H - h,
            BW - 1.0
        ));
    }
    format!("<svg width=\"{w}\" height=\"{H}\">{bars}</svg>")
}

/// How many per-slot decision rows the `/debug` dashboard renders for the
/// latest plan before deferring the rest to `/v1/status`.
const DEBUG_MAX_DECISION_ROWS: usize = 64;

/// The `GET /debug` dashboard: one self-contained HTML document — inline
/// CSS, inline SVG sparklines, a 2-second meta refresh, and zero external
/// asset references — rendering the live report, every recorded time
/// series, sampled histograms, and the plan-provenance ledger (changed
/// slots first).
pub fn debug_html(
    r: &ServerReport,
    obs: Option<&ObservatorySnapshot>,
    plans: &[PlanRecord],
) -> String {
    let mut s = String::with_capacity(16 * 1024);
    s.push_str("<!doctype html>\n<html lang=\"en\">\n<head>\n<meta charset=\"utf-8\">\n");
    s.push_str("<meta http-equiv=\"refresh\" content=\"2\">\n<title>mxmoe observatory</title>\n");
    s.push_str("<style>\n");
    s.push_str("body{font-family:monospace;margin:1.5em;background:#101418;color:#d8dee4}\n");
    s.push_str("h1,h2{font-weight:normal;color:#8ab4f8}\n");
    s.push_str("table{border-collapse:collapse;margin:.5em 0}\n");
    s.push_str("td,th{border:1px solid #2a3138;padding:2px 8px;text-align:right}\n");
    s.push_str("th{color:#9aa5b1}\ntd.l,th.l{text-align:left}\n");
    s.push_str("svg{vertical-align:middle}\n.dim{color:#788391}\n");
    s.push_str("</style>\n</head>\n<body>\n<h1>mxmoe fleet observatory</h1>\n");
    s.push_str(&format!(
        "<p class=\"dim\">generation {} · {} replica(s) · {} admitted · {} served · \
         decode {:.1} tok/s · kv {}/{} tokens @ {:.1} bits · {} replans · {} swaps</p>\n",
        r.generation,
        r.replicas,
        r.admitted,
        r.requests,
        r.decode_tps,
        r.kv_used_tokens,
        r.kv_budget_tokens,
        r.kv_avg_bits,
        r.replans,
        r.swaps
    ));
    s.push_str("<h2>time series</h2>\n");
    match obs {
        Some(snap) if !snap.series.is_empty() => {
            s.push_str(
                "<table>\n<tr><th class=\"l\">series</th><th>kind</th><th>last</th><th>min</th>\
                 <th>max</th><th class=\"l\">trend</th><th>pushed</th></tr>\n",
            );
            for sr in &snap.series {
                let last = sr.points.last().map(|p| p.v).unwrap_or(0.0);
                let lo = sr.points.iter().map(|p| p.v).fold(f64::INFINITY, f64::min);
                let hi = sr.points.iter().map(|p| p.v).fold(f64::NEG_INFINITY, f64::max);
                s.push_str(&format!(
                    "<tr><td class=\"l\">{}</td><td>{}</td><td>{:.3}</td><td>{:.3}</td>\
                     <td>{:.3}</td><td class=\"l\">{}</td><td>{}</td></tr>\n",
                    html_escape(&sr.name),
                    sr.kind.name(),
                    last,
                    if lo.is_finite() { lo } else { 0.0 },
                    if hi.is_finite() { hi } else { 0.0 },
                    sparkline_svg(&sr.points),
                    sr.pushed
                ));
            }
            s.push_str("</table>\n");
        }
        _ => s.push_str(
            "<p class=\"dim\">sampling off — enable the cluster sample config to record \
             time series.</p>\n",
        ),
    }
    if let Some(snap) = obs {
        if !snap.histograms.is_empty() {
            s.push_str(
                "<h2>histograms</h2>\n<table>\n<tr><th class=\"l\">histogram</th><th>count</th>\
                 <th>mean</th><th class=\"l\">buckets</th></tr>\n",
            );
            for h in &snap.histograms {
                let mean = if h.count == 0 { 0.0 } else { h.sum / h.count as f64 };
                s.push_str(&format!(
                    "<tr><td class=\"l\">{}</td><td>{}</td><td>{:.2}</td>\
                     <td class=\"l\">{}</td></tr>\n",
                    html_escape(&h.name),
                    h.count,
                    mean,
                    bars_svg(&h.counts)
                ));
            }
            s.push_str("</table>\n");
        }
    }
    s.push_str("<h2>plan provenance</h2>\n");
    if plans.is_empty() {
        s.push_str("<p class=\"dim\">no plans recorded yet.</p>\n");
    } else {
        s.push_str(
            "<table>\n<tr><th>replica</th><th>gen</th><th>at (s)</th><th class=\"l\">trigger\
             </th><th>drift</th><th>r</th><th class=\"l\">bits</th><th>changed</th></tr>\n",
        );
        for p in plans {
            s.push_str(&format!(
                "<tr><td>{}</td><td>{}</td><td>{:.2}</td><td class=\"l\">{}</td><td>{:.3}</td>\
                 <td>{:.2}</td><td class=\"l\">{:.2} → {:.2}</td><td>{}/{}</td></tr>\n",
                p.replica,
                p.generation,
                p.at_s,
                p.trigger.name(),
                p.drift,
                p.r,
                p.bits_before,
                p.bits_after,
                p.changed(),
                p.decisions.len()
            ));
        }
        s.push_str("</table>\n");
    }
    if let Some(p) = plans.last() {
        s.push_str(&format!(
            "<h2>latest plan — replica {}, generation {}</h2>\n",
            p.replica, p.generation
        ));
        s.push_str(
            "<table>\n<tr><th>layer</th><th>expert</th><th class=\"l\">scheme</th>\
             <th class=\"l\">prev</th><th>sens</th><th>freq</th><th>bits</th>\
             <th>rows/s</th></tr>\n",
        );
        let changed = p.decisions.iter().filter(|d| d.changed);
        let unchanged = p.decisions.iter().filter(|d| !d.changed);
        for (shown, d) in changed.chain(unchanged).enumerate() {
            if shown == DEBUG_MAX_DECISION_ROWS {
                break;
            }
            s.push_str(&format!(
                "<tr><td>{}</td><td>{}{}</td><td class=\"l\">{}</td><td class=\"l\">{}</td>\
                 <td>{:.4}</td><td>{:.3}</td><td>{:.2}</td><td class=\"l\">{}</td></tr>\n",
                d.layer,
                d.expert,
                if d.shared { " (shared)" } else { "" },
                html_escape(&d.quant),
                d.prev.map(|sch| sch.name()).unwrap_or("—"),
                d.sensitivity,
                d.freq,
                d.bits,
                d.speed_rows_per_s.map(|v| format!("{v:.0}")).unwrap_or_else(|| "—".to_string())
            ));
        }
        s.push_str("</table>\n");
        if p.decisions.len() > DEBUG_MAX_DECISION_ROWS {
            s.push_str(&format!(
                "<p class=\"dim\">… {} more slots — the full decision list is in \
                 /v1/status.</p>\n",
                p.decisions.len() - DEBUG_MAX_DECISION_ROWS
            ));
        }
    }
    s.push_str("</body>\n</html>\n");
    s
}

#[cfg(test)]
mod tests {
    use super::super::span::{Deadline, Outcome};
    use super::*;

    fn admit(ts: u64, req: u64) -> TraceEvent {
        TraceEvent {
            ts_us: ts,
            dur_us: 0,
            req,
            track: Track::Admission,
            kind: EventKind::Admitted { qos: "standard", priority: "normal", tokens: 8 },
        }
    }

    fn terminal(ts: u64, req: u64, outcome: Outcome) -> TraceEvent {
        TraceEvent {
            ts_us: ts,
            dur_us: 0,
            req,
            track: Track::Replica(0),
            kind: EventKind::Terminal {
                outcome,
                qos: "standard",
                queue_us: 5,
                compute_us: 10,
                stream_us: 0,
                generation: 0,
                deadline: Deadline::None,
                tokens: 8,
            },
        }
    }

    fn wave(ts: u64, dur: u64) -> TraceEvent {
        TraceEvent {
            ts_us: ts,
            dur_us: dur,
            req: 0,
            track: Track::Replica(0),
            kind: EventKind::Wave { scheme: "fp16", tile_m: 16, items: 2, rows: 20, padded: 32 },
        }
    }

    fn sample_log() -> TraceLog {
        TraceLog::merge(vec![
            (vec![admit(10, 1), admit(12, 2)], 0),
            (vec![terminal(40, 1, Outcome::Done), terminal(55, 2, Outcome::Cancelled)], 0),
            (vec![wave(20, 9)], 0),
        ])
    }

    #[test]
    fn merge_sorts_and_counts() {
        let log = sample_log();
        assert_eq!(log.len(), 5);
        for w in log.events.windows(2) {
            assert!(w[0].ts_us <= w[1].ts_us);
        }
        assert_eq!(log.admitted_ids(), vec![1, 2]);
        assert_eq!(log.terminals().len(), 2);
    }

    #[test]
    fn chrome_trace_round_trips_through_the_validator() {
        let log = sample_log();
        let text = log.chrome_trace().dump();
        let check = validate_chrome_trace(&text).expect("valid trace");
        assert_eq!(check.begins, 2);
        assert_eq!(check.ends, 2);
        assert_eq!(check.completes, 1);
    }

    #[test]
    fn validator_rejects_unmatched_begin() {
        let log = TraceLog::merge(vec![(vec![admit(10, 1)], 0)]);
        let err = validate_chrome_trace(&log.chrome_trace().dump()).unwrap_err();
        assert!(err.to_string().contains("unmatched 'b'"), "{err}");
    }

    #[test]
    fn validator_rejects_end_without_begin() {
        let log = TraceLog::merge(vec![(vec![terminal(10, 1, Outcome::Done)], 0)]);
        let err = validate_chrome_trace(&log.chrome_trace().dump()).unwrap_err();
        assert!(err.to_string().contains("without matching 'b'"), "{err}");
    }

    #[test]
    fn validator_rejects_regressed_timestamps() {
        // hand-build a document with a regressed ts
        let text = r#"{"traceEvents":[
            {"ph":"i","s":"t","name":"a","pid":1,"tid":1,"ts":100},
            {"ph":"i","s":"t","name":"b","pid":1,"tid":1,"ts":50}
        ]}"#;
        let err = validate_chrome_trace(text).unwrap_err();
        assert!(err.to_string().contains("regressed"), "{err}");
    }

    #[test]
    fn validator_rejects_garbage_and_missing_fields() {
        assert!(validate_chrome_trace("not json").is_err());
        assert!(validate_chrome_trace(r#"{"events":[]}"#).is_err());
        assert!(validate_chrome_trace(
            r#"{"traceEvents":[{"ph":"X","name":"w","pid":1,"tid":1,"ts":1}]}"#
        )
        .is_err(), "X without dur");
    }

    #[test]
    fn jsonl_lines_parse_back() {
        let log = sample_log();
        let text = log.jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), log.len());
        for line in lines {
            let v = Json::parse(line).expect("valid jsonl line");
            assert!(v.get("ts_us").is_some());
            assert!(v.get("event").is_some());
        }
    }

    use super::super::provenance::{PlanTrigger, SlotDecision};
    use super::super::timeseries::Observatory;
    use crate::runtime::RuntimeScheme;

    fn plan_record() -> PlanRecord {
        PlanRecord {
            replica: 0,
            generation: 1,
            at_s: 0.5,
            trigger: PlanTrigger::Replan,
            drift: 0.1,
            r: 0.5,
            bits_before: 16.0,
            bits_after: 6.0,
            decisions: vec![SlotDecision {
                layer: 0,
                expert: 1,
                shared: false,
                scheme: RuntimeScheme::W4A16,
                quant: "w4a16".to_string(),
                prev: Some(RuntimeScheme::Fp16),
                changed: true,
                sensitivity: 0.01,
                freq: 0.2,
                bits: 4.5,
                speed_rows_per_s: None,
            }],
        }
    }

    #[test]
    fn prometheus_text_lints_clean() {
        lint_prometheus(&prometheus_text(&ServerReport::default())).expect("conformant");
    }

    #[test]
    fn prometheus_histograms_lint_clean() {
        let obs = Observatory::new(8);
        for v in [0.5, 2.0, 9.0, 40.0] {
            obs.observe("queue_depth_hist", &[1.0, 4.0, 16.0], v);
        }
        let snap = obs.snapshot();
        let text = prometheus_text_with(&ServerReport::default(), Some(&snap));
        assert!(text.contains("mxmoe_queue_depth_hist_bucket{le=\"+Inf\"} 4"), "{text}");
        assert!(text.contains("mxmoe_queue_depth_hist_count 4"), "{text}");
        lint_prometheus(&text).expect("conformant with histograms");
    }

    #[test]
    fn nan_gauges_are_suppressed() {
        let r = ServerReport { kv_avg_bits: f64::NAN, ..Default::default() };
        let text = prometheus_text(&r);
        assert!(!text.contains("NaN"), "{text}");
        assert!(!text.contains("mxmoe_kv_avg_bits"), "{text}");
        lint_prometheus(&text).expect("still conformant");
    }

    #[test]
    fn label_values_escape() {
        assert_eq!(prom_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        let ok = "# HELP y g\n# TYPE y gauge\ny{k=\"a\\\"b\"} 1\n";
        lint_prometheus(ok).expect("escaped label value accepted");
    }

    #[test]
    fn lint_rejects_nonconformant_text() {
        // sample with no HELP/TYPE
        assert!(lint_prometheus("foo_total 1\n").is_err());
        // NaN sample value
        let nan = "# HELP x_total h\n# TYPE x_total counter\nx_total NaN\n";
        assert!(lint_prometheus(nan).is_err());
        // counter not ending in _total
        let bare = "# HELP x h\n# TYPE x counter\nx 1\n";
        assert!(lint_prometheus(bare).is_err());
        // histogram missing _count
        let hist = "# HELP h_x h\n# TYPE h_x histogram\nh_x_bucket{le=\"+Inf\"} 1\nh_x_sum 1\n";
        assert!(lint_prometheus(hist).is_err());
        // unescaped quote in a label value
        let label = "# HELP y g\n# TYPE y gauge\ny{k=\"a\"b\"} 1\n";
        assert!(lint_prometheus(label).is_err());
    }

    #[test]
    fn status_json_parses_and_carries_sections() {
        let obs = Observatory::new(8);
        obs.gauge("queue_depth", 0.0, 1.0);
        obs.gauge("queue_depth", 0.25, 3.0);
        let snap = obs.snapshot();
        let r = ServerReport { admitted: 7, ..Default::default() };
        let text = status_json(&r, Some(&snap), &[plan_record()]);
        let doc = Json::parse(&text).expect("valid JSON");
        assert_eq!(doc.req_str("version").unwrap(), "mxmoe-status-v1");
        let report = doc.get("report").expect("report object");
        assert_eq!(report.req_usize("admitted").unwrap(), 7);
        let series = doc.get("series").and_then(Json::as_arr).expect("series array");
        assert_eq!(series.len(), 1);
        assert_eq!(series[0].req_str("name").unwrap(), "queue_depth");
        let points = series[0].get("points").and_then(Json::as_arr).unwrap();
        assert_eq!(points.len(), 2);
        let plans = doc.get("plans").and_then(Json::as_arr).expect("plans array");
        assert_eq!(plans.len(), 1);
        let decisions = plans[0].get("decisions").and_then(Json::as_arr).expect("decisions");
        assert_eq!(decisions[0].req_str("scheme").unwrap(), "w4a16");
        assert_eq!(decisions[0].req_str("prev").unwrap(), "fp16");
    }

    #[test]
    fn debug_html_is_self_contained() {
        let obs = Observatory::new(8);
        obs.gauge("decode_tps", 0.0, 5.0);
        obs.gauge("decode_tps", 0.5, 6.0);
        obs.observe("queue_depth_hist", &[1.0, 4.0], 2.0);
        let snap = obs.snapshot();
        let html = debug_html(&ServerReport::default(), Some(&snap), &[plan_record()]);
        assert!(html.starts_with("<!doctype html>"), "doctype first");
        assert!(html.contains("<svg"), "inline sparkline");
        assert!(html.contains("decode_tps"), "series listed");
        assert!(html.contains("w4a16"), "provenance listed");
        assert!(!html.contains("http://") && !html.contains("https://"), "no external assets");
        assert!(!html.contains("<script"), "no scripts");
    }
}
