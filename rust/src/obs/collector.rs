//! Bounded per-thread span collectors (DESIGN.md §Observability).
//!
//! Every serving thread owns exactly one [`SpanCollector`] — the router
//! and each replica hold theirs directly; admission events are recorded
//! under the admission mutex the front door already takes. No new lock is
//! taken anywhere on the hot path, and a disabled collector reduces every
//! record to one branch.

use super::span::{EventKind, Track, TraceClock, TraceEvent};

/// Runtime on/off switch + ring capacity. Compile-free: flipping
/// `enabled` requires no feature flag or rebuild, and the disabled path
/// records nothing (measured ≤ 3% tokens/s overhead when enabled — see
/// `benches/bench_trace_overhead.rs`).
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    pub enabled: bool,
    /// Events retained per collector; older events are overwritten
    /// (bounded memory on a long-running server, like the metric windows).
    pub capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { enabled: false, capacity: 1 << 16 }
    }
}

impl TraceConfig {
    /// Tracing on with the default ring capacity.
    pub fn on() -> TraceConfig {
        TraceConfig { enabled: true, ..TraceConfig::default() }
    }
}

/// A bounded ring of [`TraceEvent`]s owned by one thread. Uses the same
/// cursor-ring idiom as the metric latency windows: fill, then overwrite
/// oldest-first, counting what was dropped.
#[derive(Debug)]
pub struct SpanCollector {
    clock: TraceClock,
    track: Track,
    enabled: bool,
    capacity: usize,
    buf: Vec<TraceEvent>,
    cursor: usize,
    dropped: usize,
}

impl SpanCollector {
    pub fn new(clock: TraceClock, track: Track, cfg: TraceConfig) -> SpanCollector {
        SpanCollector {
            clock,
            track,
            enabled: cfg.enabled,
            capacity: cfg.capacity.max(1),
            buf: Vec::new(),
            cursor: 0,
            dropped: 0,
        }
    }

    /// A no-op collector (tracing off) — what every metrics object starts
    /// with until the cluster enables tracing.
    pub fn disabled(track: Track) -> SpanCollector {
        SpanCollector::new(TraceClock::new(), track, TraceConfig::default())
    }

    /// Guard for callers whose event arguments are expensive to compute.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn now_us(&self) -> u64 {
        self.clock.now_us()
    }

    /// Record an instant (or request-lifecycle) event stamped now.
    pub fn instant(&mut self, req: u64, kind: EventKind) {
        if !self.enabled {
            return;
        }
        let ts_us = self.clock.now_us();
        self.push(TraceEvent { ts_us, dur_us: 0, req, track: self.track, kind });
    }

    /// Record a complete span with an explicit start and duration (both in
    /// clock microseconds).
    pub fn span(&mut self, ts_us: u64, dur_us: u64, req: u64, kind: EventKind) {
        if !self.enabled {
            return;
        }
        self.push(TraceEvent { ts_us, dur_us, req, track: self.track, kind });
    }

    fn push(&mut self, ev: TraceEvent) {
        if self.buf.len() < self.capacity {
            self.buf.push(ev);
        } else {
            self.buf[self.cursor] = ev;
            self.cursor = (self.cursor + 1) % self.capacity;
            self.dropped += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Take the recorded events (oldest first) and the overwrite count,
    /// leaving the collector empty. Called once per thread at drain time.
    pub fn drain(&mut self) -> (Vec<TraceEvent>, usize) {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.cursor..]);
        out.extend_from_slice(&self.buf[..self.cursor]);
        self.buf.clear();
        self.cursor = 0;
        let dropped = self.dropped;
        self.dropped = 0;
        (out, dropped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev_req(c: &mut SpanCollector, req: u64) {
        c.instant(req, EventKind::Routed { replica: 0 });
    }

    #[test]
    fn disabled_collector_records_nothing() {
        let mut c = SpanCollector::disabled(Track::Router);
        assert!(!c.enabled());
        ev_req(&mut c, 1);
        c.span(0, 10, 0, EventKind::SwapStage { changes: 1 });
        assert!(c.is_empty());
        let (events, dropped) = c.drain();
        assert!(events.is_empty());
        assert_eq!(dropped, 0);
    }

    #[test]
    fn ring_bounds_and_preserves_order() {
        let cfg = TraceConfig { enabled: true, capacity: 4 };
        let mut c = SpanCollector::new(TraceClock::new(), Track::Replica(2), cfg);
        for i in 1..=10u64 {
            ev_req(&mut c, i);
        }
        assert_eq!(c.len(), 4, "ring is bounded");
        let (events, dropped) = c.drain();
        assert_eq!(dropped, 6);
        let ids: Vec<u64> = events.iter().map(|e| e.req).collect();
        assert_eq!(ids, vec![7, 8, 9, 10], "oldest-first, newest retained");
        for w in events.windows(2) {
            assert!(w[0].ts_us <= w[1].ts_us, "drain order is time order");
        }
        assert!(events.iter().all(|e| e.track == Track::Replica(2)));
    }

    #[test]
    fn drain_resets_the_collector() {
        let mut c =
            SpanCollector::new(TraceClock::new(), Track::Admission, TraceConfig::on());
        ev_req(&mut c, 1);
        let (events, _) = c.drain();
        assert_eq!(events.len(), 1);
        assert!(c.is_empty());
        ev_req(&mut c, 2);
        let (events, dropped) = c.drain();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].req, 2);
        assert_eq!(dropped, 0);
    }
}
