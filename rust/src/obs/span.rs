//! Span vocabulary: the trace clock, event tracks, and the typed event
//! kinds every pipeline stage records (DESIGN.md §Observability).

use std::sync::Arc;
use std::time::Instant;

/// Shared monotonic origin for trace timestamps. One clock is created per
/// cluster and cloned into every collector (admission, router, replicas),
/// so timestamps from different threads are directly comparable and the
/// merged log sorts into one monotonic timeline.
#[derive(Clone, Debug)]
pub struct TraceClock(Arc<Instant>);

impl TraceClock {
    pub fn new() -> TraceClock {
        TraceClock(Arc::new(Instant::now()))
    }

    /// Microseconds since the clock origin.
    pub fn now_us(&self) -> u64 {
        self.0.elapsed().as_micros() as u64
    }
}

impl Default for TraceClock {
    fn default() -> Self {
        TraceClock::new()
    }
}

/// Which thread's collector recorded an event — becomes the `tid` of the
/// exported Chrome trace, so Perfetto shows one lane per serving thread.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Track {
    /// The admission front door (events recorded under the admission lock).
    Admission,
    /// The router thread (batch cuts, routing decisions, cut-time sheds).
    Router,
    /// The HTTP front door (connection lifecycle spans; handler threads
    /// share one lane — connections are short relative to lane zoom).
    Http,
    /// A replica worker thread (execution, decode, replan, terminals).
    Replica(usize),
}

impl Track {
    /// Stable Chrome-trace thread id (pid is always 1).
    pub fn tid(&self) -> u64 {
        match self {
            Track::Admission => 0,
            Track::Router => 1,
            Track::Http => 2,
            Track::Replica(i) => 10 + *i as u64,
        }
    }

    pub fn name(&self) -> String {
        match self {
            Track::Admission => "admission".to_string(),
            Track::Router => "router".to_string(),
            Track::Http => "http".to_string(),
            Track::Replica(i) => format!("replica-{i}"),
        }
    }
}

/// How a request's lifecycle ended — every admitted request records
/// exactly one terminal event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Served: a response was delivered.
    Done,
    /// Cancelled by the client (shed at the batcher, the deque, or the
    /// decode loop — anywhere after admission).
    Cancelled,
    /// Dropped by an engine failure.
    Failed,
    /// Shed by the router at cut time (cancellation observed at the cut).
    Shed,
}

impl Outcome {
    pub fn name(&self) -> &'static str {
        match self {
            Outcome::Done => "done",
            Outcome::Cancelled => "cancelled",
            Outcome::Failed => "failed",
            Outcome::Shed => "shed",
        }
    }
}

/// Deadline verdict stamped on a served request's terminal event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Deadline {
    /// The request carried no deadline.
    None,
    /// Served before its deadline.
    Hit,
    /// Served after its deadline.
    Miss,
}

impl Deadline {
    pub fn name(&self) -> &'static str {
        match self {
            Deadline::None => "none",
            Deadline::Hit => "hit",
            Deadline::Miss => "miss",
        }
    }
}

/// One recorded event. Request-lifecycle kinds carry the request id in
/// `req` (0 = not request-scoped); `dur_us` is nonzero only for complete
/// spans (waves, decode steps, replan phases).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub ts_us: u64,
    pub dur_us: u64,
    /// Request id (admission-assigned, starts at 1); 0 for engine/router
    /// spans that are not tied to one request.
    pub req: u64,
    pub track: Track,
    pub kind: EventKind,
}

/// The span taxonomy. String fields are `&'static str` names (QoS class,
/// priority, reject reason, runtime scheme) so recording never allocates.
#[derive(Clone, Debug)]
pub enum EventKind {
    /// Request admitted — opens the request's async span.
    Admitted { qos: &'static str, priority: &'static str, tokens: usize },
    /// Request rejected at the front door (load shed) — instant; the id
    /// makes the rejection attributable per request.
    Rejected { reason: &'static str },
    /// Router cut a batch (instant on the router track).
    BatchCut { seqs: usize, tokens: usize, fill: f64 },
    /// Request routed to a replica (instant on the router track).
    Routed { replica: usize },
    /// Terminal event — closes the request's async span. Time-in-stage
    /// breakdown: `queue_us` (admission → execution start), `compute_us`
    /// (execution start → finish), `stream_us` (first streamed token →
    /// finish, decode only). `generation` is the precision-plan generation
    /// that served the request (served-bits attribution).
    Terminal {
        outcome: Outcome,
        qos: &'static str,
        queue_us: u64,
        compute_us: u64,
        stream_us: u64,
        generation: u64,
        deadline: Deadline,
        tokens: usize,
    },
    /// One grouped-dispatch wave (complete span on the replica track).
    Wave { scheme: &'static str, tile_m: usize, items: usize, rows: usize, padded: usize },
    /// One decode step (complete span): mixed prefill/decode rows, tokens
    /// emitted, and KV-pool occupancy after the step.
    DecodeStep {
        rows: usize,
        prefill_rows: usize,
        decode_rows: usize,
        tokens: usize,
        kv_reserved: usize,
        kv_used: usize,
        kv_budget: usize,
    },
    /// A generation was preempted to free KV pages for an older sequence
    /// (instant on the replica track, request-scoped; not terminal — the
    /// sequence replays later). Carries the pool state after the pages
    /// were reclaimed.
    KvPreempt { kv_reserved: usize, kv_budget: usize },
    /// Drift check + MCKP re-solve on the serving thread (complete span).
    ReplanSolve { drift: f64, changes: usize },
    /// Off-thread re-quantization of the changed slots (complete span,
    /// placed at its measured duration ending at the install poll).
    SwapStage { changes: usize },
    /// Generation-counted slot flip on the serving thread (complete span).
    SwapInstall { swapped: usize, generation: u64 },
    /// One HTTP connection served (complete span on the http track):
    /// endpoint, response status, bytes written, SSE events streamed, and
    /// whether the client disconnected mid-stream. `req` carries the
    /// admission-assigned id when the connection reached admission.
    HttpConn {
        endpoint: &'static str,
        status: u16,
        bytes: usize,
        events: usize,
        disconnected: bool,
    },
}

impl EventKind {
    /// Exported event name (Chrome trace `name` field).
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Admitted { .. } | EventKind::Terminal { .. } => "request",
            EventKind::Rejected { .. } => "rejected",
            EventKind::BatchCut { .. } => "batch-cut",
            EventKind::Routed { .. } => "routed",
            EventKind::Wave { .. } => "wave",
            EventKind::DecodeStep { .. } => "decode-step",
            EventKind::KvPreempt { .. } => "kv-preempt",
            EventKind::ReplanSolve { .. } => "replan-solve",
            EventKind::SwapStage { .. } => "swap-stage",
            EventKind::SwapInstall { .. } => "swap-install",
            EventKind::HttpConn { .. } => "http-conn",
        }
    }

    /// Is this a request-terminal kind (closes the request's async span)?
    pub fn is_terminal(&self) -> bool {
        matches!(self, EventKind::Terminal { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotonic_across_clones() {
        let clock = TraceClock::new();
        let other = clock.clone();
        let a = clock.now_us();
        let b = other.now_us();
        let c = clock.now_us();
        assert!(a <= b && b <= c);
    }

    #[test]
    fn track_tids_are_distinct() {
        let tracks = [
            Track::Admission,
            Track::Router,
            Track::Http,
            Track::Replica(0),
            Track::Replica(1),
        ];
        for (i, a) in tracks.iter().enumerate() {
            for b in &tracks[i + 1..] {
                assert_ne!(a.tid(), b.tid());
            }
        }
    }
}
