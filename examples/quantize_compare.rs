//! Compare quantization methods at equal stored bits: RTN vs GPTQ vs
//! Hadamard+GPTQ (= the paper's GPTQ* / MxMoE pipeline ingredients),
//! uniform W3-class weight-only.
//!
//! ```bash
//! cargo run --release --example quantize_compare [model]
//! ```

use anyhow::Result;
use mxmoe::alloc::{calibrate, Allocation};
use mxmoe::harness::{
    build_quantized, evaluate, evaluate_fp32, hadamard_signs_for_seed, load_corpus, load_model,
    QuantMethod,
};
use mxmoe::quant::QuantScheme;

fn main() -> Result<()> {
    let model = std::env::args().skip(1).find(|a| !a.starts_with('-')).unwrap_or_else(|| "qwen15-mini".into());
    let (cfg, lm) = load_model(&model)?;
    let corpus = load_corpus()?;
    let seqs = corpus.sequences("train", cfg.seq_len);
    let calib: Vec<&[u32]> = seqs.iter().take(8).copied().collect();

    let scheme = QuantScheme::W3A16G128;
    let alloc = Allocation::uniform(&cfg, scheme);
    println!(
        "{model} @ uniform {} ({:.2} stored bits)\n",
        scheme.name(),
        alloc.avg_weight_bits(&cfg)
    );

    let fp32 = evaluate_fp32(&lm, &corpus, 16, 12);
    println!("{:<16} ppl {:>8.3}  probes {:>6.3}", "fp32", fp32.ppl, fp32.probes.mean());

    let seed = 3;
    let stats_plain = calibrate(&lm, &calib, None)?;
    let signs = hadamard_signs_for_seed(&cfg, seed);
    let stats_rot = calibrate(&lm, &calib, Some((&signs.0, &signs.1)))?;

    let mut results = Vec::new();
    for (name, method, stats) in [
        ("RTN", QuantMethod::Rtn, &stats_plain),
        ("GPTQ", QuantMethod::Gptq, &stats_plain),
        ("Hadamard+RTN", QuantMethod::HadamardRtn, &stats_rot),
        ("Hadamard+GPTQ", QuantMethod::HadamardGptq, &stats_rot),
    ] {
        let blocks = build_quantized(&lm, &alloc, method, stats, seed)?;
        let rep = evaluate(&lm, &corpus, &alloc, &blocks, 16, 12);
        println!("{name:<16} ppl {:>8.3}  probes {:>6.3}", rep.ppl, rep.probes.mean());
        results.push((name, rep.ppl));
    }

    // the method ordering the paper's pipeline relies on
    let ppl_of = |n: &str| results.iter().find(|(name, _)| *name == n).unwrap().1;
    assert!(
        ppl_of("GPTQ") <= ppl_of("RTN") * 1.02,
        "GPTQ should not lose to RTN"
    );
    println!("\nOK — error-compensating quantization recovers accuracy at 3 bits.");
    Ok(())
}
