//! Reproduce Tab. 7: the mixed-precision scheme MxMoE allocates for
//! qwen15-mini at W5A5, r = 0.75 — printed per (expert, gate/up/down),
//! plus the predicted loss/time trade-off across r.
//!
//! ```bash
//! cargo run --release --example allocate_plan [model]
//! ```

use anyhow::Result;
use mxmoe::alloc::{allocate, calibrate, measure_sensitivity, AllocatorConfig, Granularity};
use mxmoe::costmodel::GpuSpec;
use mxmoe::harness::{load_corpus, load_model};
use mxmoe::quant::SchemeRegistry;

fn main() -> Result<()> {
    let model = std::env::args().skip(1).find(|a| !a.starts_with('-')).unwrap_or_else(|| "qwen15-mini".into());
    let (cfg, lm) = load_model(&model)?;
    let corpus = load_corpus()?;
    let seqs = corpus.sequences("train", cfg.seq_len);
    let calib: Vec<&[u32]> = seqs.iter().take(8).copied().collect();
    eprintln!("calibrating...");
    let stats = calibrate(&lm, &calib, None)?;
    let registry = SchemeRegistry::weight_activation();
    eprintln!("measuring sensitivity...");
    let sens = measure_sensitivity(&lm, &stats, &registry)?;
    let gpu = GpuSpec::rtx4090();

    let alloc = allocate(
        &lm,
        &gpu,
        &registry,
        &stats,
        &sens,
        &AllocatorConfig {
            r: 0.75,
            target_avg_bits: 5.0,
            granularity: Granularity::LinearBlock,
            batch_tokens: 512,
        },
    )?;

    // ---- Tab. 7-style dump for the middle MoE layer ----
    let mid = alloc.schemes.len() / 2;
    println!(
        "# Tab. 7 analogue — {model}, layer {}, W{:.2}A{:.2}, r=0.75",
        alloc.layers[mid],
        alloc.avg_weight_bits(&cfg),
        alloc.avg_act_bits(&cfg)
    );
    println!("| expert | gate            | up              | down            |");
    println!("|--------|-----------------|-----------------|-----------------|");
    for (e, schemes) in alloc.schemes[mid].iter().enumerate() {
        let tag = if e >= cfg.n_experts { " (shared)" } else { "" };
        println!(
            "| {e:>4}{tag:<8} | {:<15} | {:<15} | {:<15} |",
            schemes[0].name(),
            schemes[1].name(),
            schemes[2].name()
        );
    }

    // ---- scheme histogram (the paper's headline observation: down_proj
    //      gets more 8-bit assignments than gate/up) ----
    let mut per_linear = [[0usize; 2]; 3]; // [linear][is_8bit]
    for block in &alloc.schemes {
        for ex in block {
            for (j, s) in ex.iter().enumerate() {
                per_linear[j][(s.wbits == 8) as usize] += 1;
            }
        }
    }
    println!("\n# 8-bit share per linear kind (sensitivity heterogeneity):");
    for (j, name) in ["gate_proj", "up_proj", "down_proj"].iter().enumerate() {
        let total = per_linear[j][0] + per_linear[j][1];
        println!(
            "  {name}: {}/{} blocks at 8 bits ({:.0}%)",
            per_linear[j][1],
            total,
            100.0 * per_linear[j][1] as f64 / total as f64
        );
    }

    // machine-readable plan
    let json_path = mxmoe::harness::artifacts_dir().join(format!("plan_{model}_w5a5.json"));
    std::fs::write(&json_path, alloc.to_json().pretty())?;
    println!("\nwrote {}", json_path.display());
    Ok(())
}
