//! END-TO-END DRIVER (DESIGN.md §6): the full system on a real workload.
//!
//! Loads the trained qwen15-mini MoE LM, runs MxMoE calibration +
//! allocation, quantizes experts per the plan, then serves a batched
//! synthetic request stream through the rust coordinator — expert FFNs
//! execute on AOT PJRT executables (Python nowhere on this path) — and
//! reports throughput, latency percentiles and scoring quality vs fp16.
//!
//! ```bash
//! make corpus models artifacts
//! cargo run --release --example serve_mixed_precision
//! ```

use std::time::Duration;

use anyhow::Result;
use mxmoe::alloc::{
    activation_frequencies, allocate, calibrate, measure_sensitivity, Allocation,
    AllocatorConfig, Granularity,
};
use mxmoe::coordinator::{Cluster, ClusterConfig, OnlineConfig, ServeConfig, Server};
use mxmoe::costmodel::GpuSpec;
use mxmoe::coordinator::slo_class_name;
use mxmoe::harness::{artifacts_dir, fast_mode, load_corpus, load_model};
use mxmoe::obs::TraceConfig;
use mxmoe::quant::{QuantScheme, SchemeRegistry};
use mxmoe::serve::{
    Admission, AdmissionConfig, FinishReason, Priority, QosClass, ReplanConfig, Replanner,
    ServeRequest, StreamEvent,
};
use mxmoe::util::Rng;

fn main() -> Result<()> {
    let model = "qwen15-mini"; // serving shapes match the AOT export
    let (cfg, lm) = load_model(model)?;
    let corpus = load_corpus()?;
    let n_requests = if fast_mode() { 8 } else { 48 };

    // ---- MxMoE allocation ----
    let seqs = corpus.sequences("train", cfg.seq_len);
    let calib: Vec<&[u32]> = seqs.iter().take(8).copied().collect();
    eprintln!("calibrating + allocating...");
    let stats = calibrate(&lm, &calib, None)?;
    let registry = SchemeRegistry::weight_activation();
    let sens = measure_sensitivity(&lm, &stats, &registry)?;
    let mx_alloc = allocate(
        &lm,
        &GpuSpec::rtx4090(),
        &registry,
        &stats,
        &sens,
        &AllocatorConfig {
            r: 0.75,
            target_avg_bits: 5.0,
            granularity: Granularity::LinearBlock,
            batch_tokens: 512,
        },
    )?;
    eprintln!(
        "plan: {:.2} avg weight bits / {:.2} avg act bits",
        mx_alloc.avg_weight_bits(&cfg),
        mx_alloc.avg_act_bits(&cfg)
    );

    let weights_path = artifacts_dir().join(format!("model_{model}.mxt"));
    let mut results = Vec::new();
    for (label, alloc) in [
        ("fp16 (baseline)", Allocation::uniform(&cfg, QuantScheme::FP16)),
        ("uniform w8a8", Allocation::uniform(&cfg, QuantScheme::W8A8)),
        ("MxMoE mixed (~5b)", mx_alloc.clone()),
    ] {
        eprintln!("serving with {label}...");
        let server = Server::start(
            cfg.clone(),
            weights_path.clone(),
            artifacts_dir(),
            alloc,
            ServeConfig { max_batch_seqs: 8, max_wait: Duration::from_millis(10), ..Default::default() },
        )?;
        // fire a request stream from "clients"
        let mut rng = Rng::new(0x5E12);
        let eval_seqs = corpus.sequences("valid", cfg.seq_len);
        let mut receivers = Vec::new();
        for _ in 0..n_requests {
            let seq = eval_seqs[rng.below(eval_seqs.len() as u64) as usize].to_vec();
            receivers.push(server.submit(seq)?);
        }
        let mut nll_sum = 0.0;
        for rx in receivers {
            let resp = rx.recv_timeout(Duration::from_secs(600)).expect("response");
            nll_sum += resp.mean_nll;
        }
        let report = server.shutdown();
        let ppl = (nll_sum / n_requests as f64).exp();
        println!(
            "{label:<18} | {:>8.1} tok/s | p50 {:>7.1} ms | p99 {:>7.1} ms | served-ppl {:>7.3} | {} expert calls, {:.0}% pad",
            report.throughput_tps,
            report.p50_latency_s * 1e3,
            report.p99_latency_s * 1e3,
            ppl,
            report.expert_calls,
            report.padding_ratio * 100.0
        );
        results.push((label, report.throughput_tps, ppl));
    }

    // sanity: MxMoE quality ≈ fp16 on the served stream
    let fp16_ppl = results[0].2;
    let mx_ppl = results[2].2;
    assert!(
        mx_ppl < fp16_ppl * 1.15,
        "MxMoE served ppl {mx_ppl} degraded >15% vs fp16 {fp16_ppl}"
    );
    println!("\nE2E OK — mixed-precision serving preserves quality (ppl {mx_ppl:.3} vs fp16 {fp16_ppl:.3}).");
    println!("(CPU-PJRT wall-clock is not a GPU perf proxy — Fig. 2/5 shapes come from the simulator benches.)");

    // ---- sharded serving: N replicas behind the expert-affinity router ----
    // Same plan — the cluster shards the serve queue across replica
    // engines (one PJRT client each); the router scores each cut batch
    // against every replica's plan (speeds measured from live wave
    // telemetry once warmed up) and work stealing mops up any imbalance.
    // The stream goes through the typed QoS front door: a bounded
    // admission queue, per-request priorities and deadlines, cancellable
    // tickets.
    let n_replicas = 2;
    eprintln!("serving with MxMoE mixed on a {n_replicas}-replica cluster (QoS front door)...");
    let cluster = Cluster::start(
        cfg.clone(),
        weights_path.clone(),
        artifacts_dir(),
        mx_alloc.clone(),
        ClusterConfig {
            replicas: n_replicas,
            serve: ServeConfig {
                max_batch_seqs: 8,
                max_wait: Duration::from_millis(10),
                ..Default::default()
            },
            // small bound so the burst below visibly load-sheds
            admission: AdmissionConfig { max_queued_seqs: 24, ..Default::default() },
            ..Default::default()
        },
    )?;
    let mut rng = Rng::new(0x5E12);
    let eval_seqs = corpus.sequences("valid", cfg.seq_len);
    let mut tickets = Vec::new();
    let mut rejected = 0usize;
    for i in 0..3 * n_requests {
        let seq = eval_seqs[rng.below(eval_seqs.len() as u64) as usize].to_vec();
        // mixed QoS: every 4th request is interactive High with a
        // deadline; the rest are Normal
        let req = if i % 4 == 0 {
            ServeRequest::new(seq)
                .priority(Priority::High)
                .qos(QosClass::Interactive)
                .deadline(Duration::from_secs(30))
        } else {
            ServeRequest::new(seq)
        };
        match cluster.try_submit(req)? {
            Admission::Admitted(t) => tickets.push(t),
            Admission::Rejected { .. } => rejected += 1,
        }
    }
    // cancel a slice mid-queue: the tickets never yield a response and the
    // queued work is shed, not executed
    let n_cancelled = tickets.len() / 8;
    for t in tickets.iter().rev().take(n_cancelled) {
        t.cancel();
    }
    for t in &tickets {
        if !t.is_cancelled() {
            t.wait_timeout(Duration::from_secs(600)).expect("response");
        }
    }
    let creport = cluster.shutdown();
    println!(
        "cluster ×{n_replicas}         | {:>8.1} tok/s | routed {:?} | {} stolen | per-replica batches {:?}",
        creport.throughput_tps(),
        creport.router.routed,
        creport.total_steals(),
        creport.replicas.iter().map(|r| r.executed_batches).collect::<Vec<_>>(),
    );
    let p99 = creport.queue_wait_p99_by_priority();
    println!(
        "front door         | {} admitted | {} rejected | {} cancelled | queue-wait p99 high {:.1} ms vs normal {:.1} ms",
        creport.admission.admitted,
        rejected,
        creport.admission.cancelled,
        p99[Priority::High.index()] * 1e3,
        p99[Priority::Normal.index()] * 1e3,
    );
    assert_eq!(
        creport.admission.admitted,
        creport.total_requests() + creport.admission.unserved(),
        "front-door accounting: admitted == responses + cancelled + failed"
    );
    // SLO accounting per QoS class: deadline-hit rate and where served
    // time went (queue vs compute) — DESIGN.md §Observability
    for (i, s) in creport.slo_by_class().iter().enumerate() {
        if s.served > 0 {
            println!(
                "slo {:<14} | {:>3} served | hit-rate {:.2} | queue {:>7.1} ms | compute {:>7.1} ms",
                slo_class_name(i),
                s.served,
                s.hit_rate(),
                1e3 * s.queue_s / s.served as f64,
                1e3 * s.compute_s / s.served as f64,
            );
        }
    }

    // ---- token-level decode: KV-cached generation with streaming ----
    // Prompts prefill once into the replica's KV cache; each subsequent
    // token costs one single-token decode row, batched across concurrent
    // generations per step (DESIGN.md §Decode-Loop). Tokens stream onto
    // the ticket as steps land; one generation is cancelled mid-stream and
    // stops within a step, its KV reservation reclaimed.
    eprintln!("serving generations through the decode loop...");
    let server = Server::start(
        cfg.clone(),
        weights_path.clone(),
        artifacts_dir(),
        mx_alloc.clone(),
        ServeConfig { max_batch_seqs: 8, max_wait: Duration::from_millis(10), ..Default::default() },
    )?;
    let max_new = if fast_mode() { 8 } else { 24 };
    let mut rng = Rng::new(0x6E1);
    let gen_tickets: Vec<_> = (0..4)
        .map(|_| {
            let prompt = eval_seqs[rng.below(eval_seqs.len() as u64) as usize][..16].to_vec();
            server.generate(prompt, max_new, vec![])
        })
        .collect::<Result<_>>()?;
    // cancel the last generation after its first token arrives
    let victim = gen_tickets.last().unwrap();
    match victim.wait_event(Duration::from_secs(600))? {
        StreamEvent::Token { .. } => victim.cancel(),
        StreamEvent::Done { .. } => {}
    }
    let mut streamed = 0usize;
    for (i, t) in gen_tickets.iter().enumerate() {
        if t.is_cancelled() {
            continue;
        }
        let (tokens, reason) = t.collect_tokens(Duration::from_secs(600))?;
        streamed += tokens.len();
        assert_eq!(tokens.len(), max_new);
        assert_eq!(reason, FinishReason::Length);
        let resp = t.wait_timeout(Duration::from_secs(600))?;
        if i == 0 {
            println!(
                "generation         | {} prompt + {} new tokens | first stream {:?}… | prompt nll {:.3}",
                16,
                tokens.len(),
                &tokens[..tokens.len().min(6)],
                resp.mean_nll
            );
        }
    }
    let dreport = server.shutdown();
    println!(
        "decode loop        | {:>8.1} gen tok/s | {} steps (p50 {:.1} ms) | {} prefill + {} decode rows | kv peak {} | {} cancelled",
        dreport.decode_tps,
        dreport.decode_steps,
        dreport.p50_step_s * 1e3,
        dreport.prefill_rows,
        dreport.decode_rows,
        dreport.kv_peak_tokens,
        dreport.cancelled,
    );
    assert!(dreport.generated_tokens >= streamed);
    assert_eq!(
        dreport.admitted,
        dreport.requests + dreport.cancelled + dreport.failed,
        "decode accounting: admitted == responses + cancelled + failed"
    );

    // ---- closed-loop demo: online telemetry + drift-adaptive replan ----
    // phase 1 replays the calibration-like corpus distribution; phase 2
    // shifts to uniform-random token streams. The server's live telemetry
    // detects the drift, re-solves the MCKP on live frequencies and
    // hot-swaps the changed experts mid-stream, without dropping requests.
    eprintln!("serving with MxMoE online (drift-adaptive)...");
    let replanner = Replanner {
        gpu: GpuSpec::rtx4090(),
        registry: registry.clone(),
        sens,
        cfg: ReplanConfig {
            drift_threshold: 0.10,
            min_tokens_between: 256,
            alloc: AllocatorConfig {
                r: 0.75,
                target_avg_bits: 5.0,
                granularity: Granularity::LinearBlock,
                batch_tokens: 512,
            },
        },
    };
    // this phase runs with lifecycle tracing on: the exported Chrome trace
    // shows admission → batch-cut → routing → waves plus the replan solve
    // and hot-swap spans the drift below triggers
    let server = Server::start_online(
        cfg.clone(),
        weights_path.clone(),
        artifacts_dir(),
        mx_alloc,
        ServeConfig {
            max_batch_seqs: 8,
            max_wait: Duration::from_millis(10),
            trace: TraceConfig::on(),
            ..Default::default()
        },
        OnlineConfig {
            replanner,
            baseline: activation_frequencies(&stats),
            ewma_alpha: Some(0.25),
        },
    )?;
    let mut rng = Rng::new(0x0A11);
    let eval_seqs = corpus.sequences("valid", cfg.seq_len);
    let mut receivers = Vec::new();
    for _ in 0..n_requests {
        let seq = eval_seqs[rng.below(eval_seqs.len() as u64) as usize].to_vec();
        receivers.push(server.submit(seq)?);
    }
    for _ in 0..n_requests {
        // workload shift: uniform-random tokens drift the routing mix
        let seq: Vec<u32> = (0..cfg.seq_len).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
        receivers.push(server.submit(seq)?);
    }
    let mut generations = Vec::new();
    for rx in receivers {
        let resp = rx.recv_timeout(Duration::from_secs(600)).expect("response");
        generations.push(resp.generation);
    }
    let report = server.shutdown();
    println!(
        "online             | {:>8.1} tok/s | p50 {:>7.1} ms | drift {:.3} | {} replan(s), {} swap(s), final gen {} | max queue {}",
        report.throughput_tps,
        report.p50_latency_s * 1e3,
        report.last_drift,
        report.replans,
        report.swaps,
        report.generation,
        report.max_queue_depth,
    );
    let trace_path = artifacts_dir().join("serve_trace.json");
    report.trace.write_chrome_trace(&trace_path)?;
    println!(
        "trace              | {} lifecycle events → {} (open at https://ui.perfetto.dev)",
        report.trace.len(),
        trace_path.display(),
    );
    if report.replans > 0 {
        let swapped_mid_stream = generations.iter().any(|&g| g > 0);
        println!(
            "closed loop OK — plan re-solved under drift{}",
            if swapped_mid_stream { ", later requests served on the new generation" } else { "" }
        );
    } else {
        println!("(no replan triggered on this stream — drift stayed under threshold)");
    }
    Ok(())
}
