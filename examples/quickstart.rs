//! Quickstart: load a trained mini MoE model, quantize its MoE blocks with
//! MxMoE at 5 average bits, and compare perplexity against fp32 and a
//! uniform baseline.
//!
//! ```bash
//! make corpus models artifacts     # once
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use mxmoe::alloc::{allocate, calibrate, measure_sensitivity, Allocation, AllocatorConfig, Granularity};
use mxmoe::costmodel::GpuSpec;
use mxmoe::harness::{
    build_quantized, evaluate, evaluate_fp32, load_corpus, load_model, QuantMethod,
};
use mxmoe::quant::{QuantScheme, SchemeRegistry};

fn main() -> Result<()> {
    let model = std::env::args().skip(1).find(|a| !a.starts_with('-')).unwrap_or_else(|| "qwen15-mini".into());
    let (cfg, lm) = load_model(&model)?;
    let corpus = load_corpus()?;
    println!(
        "model {model}: {} experts (+{} shared), top-{}",
        cfg.n_experts, cfg.n_shared, cfg.topk
    );

    // 1. calibrate
    let seqs = corpus.sequences("train", cfg.seq_len);
    let calib: Vec<&[u32]> = seqs.iter().take(8).copied().collect();
    println!("calibrating on {} sequences...", calib.len());
    let stats = calibrate(&lm, &calib, None)?;

    // 2. sensitivity + allocation (r = 0.75, 5-bit weight-activation)
    let registry = SchemeRegistry::weight_activation();
    let sens = measure_sensitivity(&lm, &stats, &registry)?;
    let alloc = allocate(
        &lm,
        &GpuSpec::rtx4090(),
        &registry,
        &stats,
        &sens,
        &AllocatorConfig {
            r: 0.75,
            target_avg_bits: 5.0,
            granularity: Granularity::LinearBlock,
            batch_tokens: 512,
        },
    )?;
    println!(
        "MxMoE allocation: {:.2} avg weight bits, {:.2} avg act bits",
        alloc.avg_weight_bits(&cfg),
        alloc.avg_act_bits(&cfg)
    );

    // 3. quantize + evaluate
    let fp32 = evaluate_fp32(&lm, &corpus, 16, 12);
    println!("fp32     : ppl {:.3}  probes {:.3}", fp32.ppl, fp32.probes.mean());

    let blocks = build_quantized(&lm, &alloc, QuantMethod::Gptq, &stats, 1)?;
    let mx = evaluate(&lm, &corpus, &alloc, &blocks, 16, 12);
    println!("MxMoE 5b : ppl {:.3}  probes {:.3}", mx.ppl, mx.probes.mean());

    let uni = Allocation::uniform(&cfg, QuantScheme::W4A4);
    let ublocks = build_quantized(&lm, &uni, QuantMethod::Rtn, &stats, 1)?;
    let u = evaluate(&lm, &corpus, &uni, &ublocks, 16, 12);
    println!("RTN w4a4 : ppl {:.3}  probes {:.3}", u.ppl, u.probes.mean());

    assert!(mx.ppl <= u.ppl, "MxMoE should beat uniform w4a4");
    println!("\nOK — MxMoE mixed precision beats uniform 4-bit at ~1 extra avg bit.");
    Ok(())
}
